"""Fleet-vectorized engine equivalence: the batched per-window advance
(``advance_pool_many`` / ``node_pass_many`` / ``submit_grouped``) is an
optimization of the per-node driver, not a model change — every test here
pins bit-identical results against the scalar path it replaced.

All traces are small and synthetic (canned device curves, no JAX) so the
tier-1 wall-clock stays bounded.
"""
import numpy as np
import pytest

import repro.cluster.cluster_sim as cluster_sim
from repro.cluster import (DiurnalTraffic, Fleet, FleetFaults, NodeKill,
                           NodeSpec, Pool, ScaledDeviceModel, make_router,
                           simulate_fleet)
from repro.cluster.backend import submit_grouped
from repro.cluster.router import (LeastOutstandingRouter, _assign_heap,
                                  _assign_scalar, _est_work_by_class)
from repro.core.latency_model import (GPU_1080TI, AnalyticalDeviceModel,
                                      TableDeviceModel)
from repro.core.simulator import (ExecPoolState, NodeEngine, SchedulerConfig,
                                  advance_pool, advance_pool_many, node_pass,
                                  node_pass_many, split_requests,
                                  split_requests_many)

pytestmark = pytest.mark.cluster

CPU = TableDeviceModel(np.array([1., 4, 16, 64, 256, 1024]),
                       np.array([.0008, .001, .0018, .0045, .015, .058]))
ACCEL = AnalyticalDeviceModel(
    flops_per_sample=5e6, mem_bytes_per_sample=1e5, in_bytes_per_sample=4e3,
    **GPU_1080TI)


def _fleet(sky=8, bdw=6, gpu=4) -> Fleet:
    return Fleet([
        Pool("sky", NodeSpec(cpu=CPU, batch_size=8, n_executors=4),
             count=sky),
        Pool("bdw", NodeSpec(cpu=ScaledDeviceModel(CPU, 1.5), batch_size=8,
                             n_executors=4), count=bdw),
        Pool("gpu", NodeSpec(cpu=CPU, accel=ACCEL, batch_size=8,
                             n_executors=4, offload_threshold=150),
             count=gpu),
    ])


def _trace(rng, horizon=6.0, qps=500.0):
    t, s = DiurnalTraffic(base_qps=qps, amplitude=0.5,
                          period_s=horizon / 2).generate(rng, horizon)
    return t, s


# ----------------------------------------------------- primitive parity


def test_split_requests_many_matches_constant_batch(rng):
    sizes = rng.integers(1, 700, 60)
    for B in (1, 8, 64):
        ref = split_requests(sizes, B)
        got = split_requests_many(sizes, np.full(len(sizes), B, np.int64))
        for a, b in zip(ref, got):
            assert np.array_equal(a, b)


def test_split_requests_many_mixed_batches_match_per_query(rng):
    sizes = rng.integers(1, 500, 40)
    batch = rng.choice([1, 4, 8, 32], 40)
    group, req_batch, bounds = split_requests_many(sizes, batch)
    starts = np.concatenate(([0], bounds[:-1]))
    for q in range(len(sizes)):
        _, rb, _ = split_requests(sizes[q:q + 1], int(batch[q]))
        assert np.array_equal(req_batch[starts[q]:bounds[q]], rb)
        assert np.all(group[starts[q]:bounds[q]] == q)


def test_split_requests_many_rejects_zero_sizes():
    with pytest.raises(ValueError):
        split_requests_many(np.array([4, 0, 2]), np.array([8, 8, 8]))


def test_advance_pool_many_matches_chained_scalar(rng):
    """Randomized multi-window trials spanning every regime: idle pools
    with room (closed form), busy pools (lockstep heap), idle-but-
    overfull pools and zero-executor pools (scalar fallback)."""
    for trial in range(20):
        cs = [int(c) for c in rng.integers(0, 5, 6)]
        states = [ExecPoolState(c) for c in cs]
        frees = [np.zeros(c) for c in cs]
        t0 = 0.0
        for _ in range(4):
            arr_segs, svc_segs = [], []
            for _ in cs:
                r = int(rng.integers(0, 12))
                arr_segs.append(np.sort(t0 + rng.uniform(0, 0.4, r)))
                svc_segs.append(rng.uniform(0.01, 0.5, r))
            bounds = np.cumsum([len(a) for a in arr_segs])
            arrivals = np.concatenate(arr_segs)
            svc = np.concatenate(svc_segs)
            out = advance_pool_many(arrivals, svc, bounds, states)
            starts = np.concatenate(([0], bounds[:-1]))
            for i in range(len(cs)):
                dep, frees[i] = advance_pool(arr_segs[i], svc_segs[i],
                                             frees[i])
                assert np.array_equal(out[starts[i]:bounds[i]], dep,
                                      equal_nan=True), (trial, i)
                assert np.array_equal(np.sort(states[i].materialize()),
                                      np.sort(frees[i]))
            # next window overlaps the backlog → busy pools go lockstep
            t0 += 0.2


def test_advance_pool_many_empty_window_keeps_state():
    st = ExecPoolState(2, t0=5.0)
    out = advance_pool_many(np.empty(0), np.empty(0), np.array([0, 0]),
                            [st, ExecPoolState(2)])
    assert len(out) == 0
    assert st.fmax == 5.0 and np.array_equal(st.materialize(), [5.0, 5.0])


def test_node_pass_many_matches_node_pass_per_segment(rng):
    """Three node classes (fast CPU, slow CPU, CPU+accel with offload),
    three windows of carried state, spans on — done and exec_start must
    match the per-node pipeline bit for bit."""
    slow = ScaledDeviceModel(CPU, 1.5)
    cfg = SchedulerConfig(batch_size=8, n_executors=2)
    acfg = SchedulerConfig(batch_size=8, n_executors=2, n_accelerators=1,
                           offload_threshold=150)
    mk = [lambda: NodeEngine.make(CPU, cfg),
          lambda: NodeEngine.make(slow, cfg),
          lambda: NodeEngine.make(CPU, acfg, accel=ACCEL)]
    engines = [mk[i % 3]() for i in range(7)]
    ref_cpu = [None] * 7
    ref_acc = [None] * 7
    t0 = 0.0
    for _ in range(3):
        arr_segs, sz_segs = [], []
        for _ in engines:
            r = int(rng.integers(0, 10))
            arr_segs.append(np.sort(t0 + rng.uniform(0, 0.3, r)))
            sz_segs.append(rng.integers(1, 600, r))
        bounds = np.cumsum([len(a) for a in arr_segs])
        done, starts = node_pass_many(np.concatenate(arr_segs),
                                      np.concatenate(sz_segs), bounds,
                                      engines, want_starts=True)
        seg0 = np.concatenate(([0], bounds[:-1]))
        for i, e in enumerate(engines):
            d, _, _, ref_cpu[i], ref_acc[i], xs = node_pass(
                arr_segs[i], sz_segs[i], e.cpu, e.cfg, accel=e.accel,
                cpu_free=ref_cpu[i], acc_free=ref_acc[i], want_starts=True)
            assert np.array_equal(done[seg0[i]:bounds[i]], d,
                                  equal_nan=True)
            assert np.array_equal(starts[seg0[i]:bounds[i]], xs,
                                  equal_nan=True)
        t0 += 0.15


def test_node_pass_many_identity_cache_is_transparent(rng):
    """Reusing one engines list (the grouped driver's steady state, cache
    hit) and rebuilding a fresh list per window (cache miss) advance the
    same state to the same answer."""
    arr = np.sort(rng.uniform(0, 1, 12))
    sz = rng.integers(1, 300, 12)
    bounds = np.array([5, 12])
    cfg = SchedulerConfig(batch_size=8, n_executors=2)
    a = [NodeEngine.make(CPU, cfg), NodeEngine.make(CPU, cfg)]
    b = [NodeEngine.make(CPU, cfg), NodeEngine.make(CPU, cfg)]
    for w in range(3):
        t = arr + 0.3 * w
        d1, _ = node_pass_many(t, sz, bounds, a)          # same list obj
        d2, _ = node_pass_many(t, sz, bounds, list(b))    # fresh list
        assert np.array_equal(d1, d2, equal_nan=True)


# ------------------------------------------------------- driver parity


@pytest.mark.parametrize("router", ["round_robin", "least_outstanding",
                                    "hetero"])
def test_grouped_driver_matches_per_node(rng, router):
    fleet = _fleet()
    t, s = _trace(rng)
    ref = simulate_fleet(t, s, fleet, make_router(router), window_s=0.25,
                         grouped=False)
    vec = simulate_fleet(t, s, fleet, make_router(router), window_s=0.25,
                         grouped=None)
    assert ref.n_queries == vec.n_queries and ref.dropped == vec.dropped
    assert ref.qps == vec.qps
    assert (ref.p50_ms, ref.p95_ms, ref.p99_ms) == \
        (vec.p50_ms, vec.p95_ms, vec.p99_ms)
    assert ref.node_hours == vec.node_hours
    assert ref.per_pool == vec.per_pool


def test_grouped_driver_telemetry_matches_per_node(rng):
    """Per-query spans are bit-identical; the metrics registry (whose
    grouped fold sums per node segment instead of per submit call) agrees
    on every count exactly and every float to 1e-9 relative."""
    fleet = _fleet(4, 3, 2)
    t, s = _trace(rng, horizon=4.0, qps=300.0)
    ref = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                         window_s=0.25, grouped=False, telemetry=True)
    vec = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                         window_s=0.25, grouped=None, telemetry=True)
    assert np.array_equal(ref.telemetry.spans.t_done,
                          vec.telemetry.spans.t_done, equal_nan=True)
    assert np.array_equal(ref.telemetry.spans.t_exec_start,
                          vec.telemetry.spans.t_exec_start, equal_nan=True)
    a = ref.telemetry.registry.snapshot(reset_window=False)
    b = vec.telemetry.registry.snapshot(reset_window=False)
    assert a.keys() == b.keys()
    for k in a:
        assert np.isclose(a[k], b[k], rtol=1e-9, atol=1e-12), (k, a[k], b[k])


def test_grouped_path_actually_taken(rng, monkeypatch):
    calls = {"n": 0}
    real = submit_grouped

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(cluster_sim, "submit_grouped", counting)
    fleet = _fleet(3, 2, 1)
    t, s = _trace(rng, horizon=2.0, qps=200.0)
    simulate_fleet(t, s, fleet, make_router("round_robin"), window_s=0.25,
                   grouped=None)
    assert calls["n"] > 0
    calls["n"] = 0
    simulate_fleet(t, s, fleet, make_router("round_robin"), window_s=0.25,
                   grouped=False)
    assert calls["n"] == 0


def test_kill_windows_fall_back_and_match_per_node(rng):
    """A fleet-fault kill forces the per-node loop (grouped eligibility
    excludes killed/orphan windows) — the grouped-default run must equal
    the grouped=False run including re-route accounting."""
    fleet = _fleet(3, 2, 1)
    t, s = _trace(rng, horizon=2.0, qps=900.0)   # oversubscribed: the
    faults = FleetFaults(kills=(NodeKill(0.5, "sky", 0),))  # kill orphans
    ref = simulate_fleet(t, s, fleet, make_router("round_robin"),
                         window_s=0.25, grouped=False, fleet_faults=faults)
    vec = simulate_fleet(t, s, fleet, make_router("round_robin"),
                         window_s=0.25, grouped=None, fleet_faults=faults)
    assert vec.rerouted > 0 and vec.rerouted == ref.rerouted
    assert ref.qps == vec.qps and ref.dropped == vec.dropped
    assert (ref.p50_ms, ref.p95_ms, ref.p99_ms) == \
        (vec.p50_ms, vec.p95_ms, vec.p99_ms)
    assert ref.per_pool == vec.per_pool


# -------------------------------------------------------- router parity


def test_least_outstanding_heap_matches_scalar_reference(rng):
    """The event-sorted heap evaluation is the O(N·Q) decay-all-argmin
    loop verbatim: same assignments across stateful windows, same
    carried backlogs."""
    nodes = _fleet(3, 2, 2).node_views()
    backlog_h = np.zeros(len(nodes))
    backlog_s = backlog_h.copy()
    lt_h = lt_s = 0.0
    t0 = 0.0
    for _ in range(4):
        q = int(rng.integers(5, 40))
        times = np.sort(t0 + rng.uniform(0, 0.5, q))
        sizes = rng.integers(1, 600, q)
        cls_of, est, _ = _est_work_by_class(nodes, sizes)
        got, backlog_h, lt_h = _assign_heap(times, est, cls_of,
                                            backlog_h, lt_h)
        ref, backlog_s, lt_s = _assign_scalar(times, est[cls_of],
                                              backlog_s, lt_s)
        assert np.array_equal(got, ref)
        np.testing.assert_allclose(backlog_h, backlog_s, atol=1e-9)
        t0 += 0.5


def test_least_outstanding_router_state_survives_resize(rng):
    """The router's keyed store re-aligns when the node list shrinks —
    the vectorized heap must keep that contract."""
    r = LeastOutstandingRouter()
    nodes = _fleet(3, 2, 0).node_views()
    t = np.sort(rng.uniform(0, 1, 30))
    s = rng.integers(1, 300, 30)
    a1 = r.assign(t, s, nodes)
    assert set(np.unique(a1)) <= set(range(len(nodes)))
    a2 = r.assign(t + 1.0, s, nodes[:3])      # resize: two nodes retired
    assert set(np.unique(a2)) <= {0, 1, 2}


def test_est_work_by_class_collapses_equal_specs(rng):
    """An N-node fleet of C classes prices queries C times, not N — and
    the class-compact rows fan out to exactly the per-node estimates."""
    nodes = _fleet(6, 4, 3).node_views()
    sizes = rng.integers(1, 600, 50)
    cls_of, est, off = _est_work_by_class(nodes, sizes)
    assert est.shape[0] == 3 and len(np.unique(cls_of)) == 3
    for i, nv in enumerate(nodes):
        from repro.cluster.router import _class_drain_seconds
        e, o = _class_drain_seconds(nv.spec, sizes)
        assert np.array_equal(est[cls_of[i]], e)
        assert np.array_equal(off[cls_of[i]], o)
