"""Pallas kernel sweeps: shapes × dtypes, interpret=True vs the jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("vocab,batch,hot,dim", [
    (64, 8, 4, 128), (128, 16, 1, 128), (1000, 8, 16, 256),
    (37, 4, 3, 130),                       # non-128 dim → wrapper pads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_kernel(vocab, batch, hot, dim, dtype):
    table = jax.random.normal(KEY, (vocab, dim)).astype(dtype)
    idx = jax.random.randint(KEY, (batch, hot), 0, vocab)
    got = ops.embedding_bag(table, idx, use_pallas=True, interpret=True)
    # oracle in f32 (the kernel accumulates f32; a bf16-accumulating oracle
    # would itself carry ~H·2⁻⁸ drift)
    want = ref.embedding_bag(table.astype(jnp.float32), idx).astype(dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_kernel_modes(mode):
    table = jax.random.normal(KEY, (50, 128))
    idx = jax.random.randint(KEY, (8, 5), 0, 50)
    got = ops.embedding_bag(table, idx, mode=mode, use_pallas=True, interpret=True)
    # the kernel accumulates with Kahan compensation, so hold it to the
    # f64-exact pooled value (up to f32 ulps of the row magnitudes) — an
    # f32 oracle with atol=0 would demand bitwise-matching *rounding order*,
    # which near-cancelling bags cannot satisfy for any other order
    rows = np.asarray(table, np.float64)[np.asarray(idx)]
    want = rows.sum(axis=1)
    if mode == "mean":
        want = want / idx.shape[1]
    np.testing.assert_allclose(np.asarray(got, np.float64), want,
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("batch,fields,dim", [
    (32, 8, 32), (64, 27, 16), (8, 4, 64), (10, 5, 130),   # odd batch → pad
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot_interaction_kernel(batch, fields, dim, dtype):
    feats = (jax.random.normal(KEY, (batch, fields, dim)) / dim ** 0.5).astype(dtype)
    got = ops.dot_interaction(feats, use_pallas=True, interpret=True)
    want = ref.dot_interaction_packed(feats)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("batch,f,h,hn,dim", [
    (8, 6, 5, 7, 128), (16, 10, 10, 4, 64), (4, 3, 8, 16, 130),
])
def test_cin_kernel(batch, f, h, hn, dim):
    x0 = jax.random.normal(KEY, (batch, f, dim)) / dim ** 0.5
    xk = jax.random.normal(jax.random.fold_in(KEY, 1), (batch, h, dim)) / dim ** 0.5
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (h * f, hn))
    got = ops.cin_layer(x0, xk, w, use_pallas=True, interpret=True)
    want = ref.cin_layer(x0, xk, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,hq,hkv,d,t", [
    (2, 8, 2, 64, 256), (4, 4, 4, 32, 128), (1, 16, 8, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_kernel(b, hq, hkv, d, t, dtype):
    q = jax.random.normal(KEY, (b, hq, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, hkv, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, hkv, d)).astype(dtype)
    pos = jax.random.randint(KEY, (b,), 1, t + 1)
    got = ops.decode_attention(q, k, v, pos, use_pallas=True, interpret=True)
    want = ref.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_flash_decode_pos_zero_vs_one():
    """pos=1 attends only to slot 0 (pos=0 would be an empty softmax —
    serving never issues it, decode always follows a ≥1-token prefill)."""
    b, hq, hkv, d, t = 1, 2, 1, 32, 128
    q = jax.random.normal(KEY, (b, hq, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, hkv, d))
    got = ops.decode_attention(q, k, v, jnp.array([1]), use_pallas=True,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(got[0, 0]), np.asarray(v[0, 0, 0]),
                               rtol=1e-5, atol=1e-5)
