"""Unit tests for the layer substrate.  (Hypothesis property tests live in
test_properties.py so these plain tests run even without the dev extras.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import attention as A
from repro.layers import embedding as E
from repro.layers import interactions as IX
from repro.layers import moe as M
from repro.layers import rnn as R

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------- embedding bag


def test_qr_embedding_covers_vocab():
    p = E.init_qr_tables(KEY, 1000, 8, num_buckets=32)
    idx = jnp.arange(1000)
    out = E.qr_lookup(p, idx)
    assert out.shape == (1000, 8)
    # distinct ids map to distinct embeddings with very high probability
    assert len(np.unique(np.asarray(out).round(5), axis=0)) > 990


# ------------------------------------------------------------ interactions


def test_dot_interaction_symmetric_pairs():
    f = jax.random.normal(KEY, (3, 5, 7))
    out = IX.dot_interaction(f)
    z = np.einsum("bfd,bgd->bfg", np.asarray(f), np.asarray(f))
    li, lj = np.tril_indices(5, k=-1)
    np.testing.assert_allclose(np.asarray(out), z[:, li, lj], rtol=1e-5)


def test_fm_identity():
    """FM pooling == explicit pairwise sum."""
    f = jax.random.normal(KEY, (4, 6, 8))
    got = IX.fm_interaction(f)
    fn = np.asarray(f)
    want = np.zeros((4, 8))
    for i in range(6):
        for j in range(6):
            if i < j:
                want += fn[:, i] * fn[:, j]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


def test_cin_shapes_and_grad():
    p = IX.init_cin(KEY, 6, 8, [10, 12])
    x = jax.random.normal(KEY, (3, 6, 8))
    out = IX.cin(p, x)
    assert out.shape == (3, 22)
    g = jax.grad(lambda pp: IX.cin(pp, x).sum())(p)
    assert all(np.isfinite(np.asarray(gi)).all() for gi in g)


def test_din_attention_mask_excludes_history():
    p = IX.init_din_attention(KEY, 8)
    hist = jax.random.normal(KEY, (2, 6, 8))
    tgt = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8))
    mask = jnp.array([[True] * 6, [True, True, False, False, False, False]])
    out = IX.din_attention(p, hist, tgt, mask=mask)
    # row 1 must not depend on masked history items
    hist2 = hist.at[1, 2:].set(99.0)
    out2 = IX.din_attention(p, hist2, tgt, mask=mask)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(out2[1]), rtol=1e-5)


def test_capsule_routing_norm_bounded():
    """Squash keeps capsule norms in (0, 1)."""
    p = IX.init_capsule_routing(KEY, 16)
    hist = jax.random.normal(KEY, (4, 20, 16)) * 3
    caps = IX.capsule_routing(p, hist, n_interests=4, n_iters=3)
    norms = np.linalg.norm(np.asarray(caps), axis=-1)
    assert (norms < 1.0 + 1e-5).all()


# -------------------------------------------------------------------- moe


def test_moe_capacity_drops_overflow():
    p = M.init_moe(KEY, 8, 16, 4, 1)
    x = jnp.ones((1, 64, 8))            # identical tokens → one expert hot
    y, aux = M.apply_moe(p, x, top_k=1, capacity_factor=0.25)
    assert float(aux["dropped_frac"]) > 0.5


# --------------------------------------------------------------- attention


def test_gqa_matches_mha_when_kv_equal():
    d, h, hd, s, b = 32, 4, 8, 10, 2
    p = A.init_attention(KEY, d, h, h, hd)
    x = jax.random.normal(KEY, (b, s, d))
    out = A.attention(p, x, n_heads=h, n_kv_heads=h, head_dim=hd, causal=True)
    assert out.shape == (b, s, d)


def test_decode_matches_full_attention():
    """Token-by-token decode must equal the full causal forward."""
    d, hq, hkv, hd, s, b = 32, 4, 2, 8, 6, 2
    p = A.init_attention(KEY, d, hq, hkv, hd)
    freqs = A.rope_freqs(hd)
    x = jax.random.normal(KEY, (b, s, d))
    full = A.attention(p, x, n_heads=hq, n_kv_heads=hkv, head_dim=hd,
                       causal=True, freqs=freqs)
    cache = A.init_kv_cache(b, s, hkv, hd)
    outs = []
    for t in range(s):
        o, cache = A.decode_attention(p, x[:, t:t + 1], cache, n_heads=hq,
                                      n_kv_heads=hkv, head_dim=hd, freqs=freqs)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=2e-4, atol=2e-4)


def test_flash_equals_dense_causal():
    b, s, hq, hkv, d = 2, 256, 4, 2, 16
    q = jax.random.normal(KEY, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hkv, d))
    mask = jnp.tril(jnp.ones((s, s), bool))[None, None]
    dense = A._sdpa(q, k, v, mask)
    fl = A.flash_sdpa(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(fl),
                               rtol=2e-5, atol=2e-5)


def test_rope_preserves_norm_and_relative_phase():
    freqs = A.rope_freqs(8)
    x = jax.random.normal(KEY, (1, 4, 2, 8))
    r = A.apply_rope(x, jnp.arange(4), freqs)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j
    q = jnp.ones((1, 8, 1, 8))
    k = jnp.ones((1, 8, 1, 8))
    qr = A.apply_rope(q, jnp.arange(8), freqs)[0, :, 0]
    kr = A.apply_rope(k, jnp.arange(8), freqs)[0, :, 0]
    d1 = float(qr[3] @ kr[1])
    d2 = float(qr[5] @ kr[3])
    assert abs(d1 - d2) < 1e-4


# -------------------------------------------------------------------- rnn


def test_gru_matches_manual_step():
    p = R.init_gru(KEY, 4, 6)
    xs = jax.random.normal(KEY, (2, 5, 4))
    hs = R.gru(p, xs)
    assert hs.shape == (2, 5, 6)
    # AUGRU with zero attention == frozen state
    h_frozen = R.augru(p, xs, jnp.zeros((2, 5)))
    np.testing.assert_allclose(np.asarray(h_frozen), np.zeros((2, 6)), atol=1e-6)
