"""Fleet lifecycle semantics: boot delays, kill/drain/re-route, the
predictive autoscaler, and traffic forecast calibration — small traces,
canned device curves (tier-1 budget)."""
import numpy as np
import pytest

from repro.cluster import (Autoscaler, BackendDied, DiurnalTraffic, Fleet,
                           FleetController, FleetFaults, MultiTenantTraffic,
                           NodeKill, NodeSpec, NodeState, Pool,
                           PredictiveAutoscaler, SelfHealPolicy,
                           SimNodeBackend, StationaryTraffic, cluster_max_qps,
                           drive_fleet, make_router, simulate_fleet)
from repro.cluster.fleet import NodeView
from repro.core.latency_model import TableDeviceModel
from repro.core.query_gen import PRODUCTION, SizeDist, sample_trace

pytestmark = pytest.mark.cluster

CPU = TableDeviceModel(np.array([1., 4, 16, 64, 256, 1024]),
                       np.array([.0008, .001, .0018, .0045, .015, .058]))


def _fleet(n=4, boot_s=0.0, max_count=None) -> Fleet:
    return Fleet([Pool("sky", NodeSpec(cpu=CPU, batch_size=8, boot_s=boot_s),
                       count=n, min_count=1, max_count=max_count)])


def _views(n=3, pool="pool"):
    spec = NodeSpec(cpu=CPU, batch_size=8, n_executors=4)
    return [NodeView(pool, i, spec, 100.0) for i in range(n)]


def _trace(n=400, qps=600.0, seed=3):
    unit, sizes = sample_trace(np.random.default_rng(seed), n)
    return unit / qps, sizes


# ------------------------------------------------------------- booting


def test_booting_node_receives_no_queries_until_boot_elapses():
    """A node added to a running fleet is BOOTING — invisible to routers —
    until its spec's boot_s has passed; the initial fleet is warm."""
    fleet = _fleet(n=1, boot_s=1.0, max_count=4)
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend)
    ctrl.start(0.0)
    serving, _ = ctrl.begin_window(0.0)
    assert len(serving) == 1                 # initial node: warm, no boot
    fleet.scale("sky", +1)                   # ordered at t=0.5
    serving, _ = ctrl.begin_window(0.5)
    assert len(serving) == 1
    assert ctrl.states()[("sky", 1)] is NodeState.BOOTING
    serving, _ = ctrl.begin_window(1.2)      # 0.5 + 1.0 = 1.5 not yet due
    assert len(serving) == 1
    serving, _ = ctrl.begin_window(1.5)
    assert len(serving) == 2
    assert ctrl.states()[("sky", 1)] is NodeState.SERVING
    assert ctrl.billable_n == 2              # booting nodes were billed


def test_boot_delay_visible_in_lifecycle_events():
    """End-to-end: every autoscaled node's BOOTING→SERVING gap ≥ boot_s
    (rounded up to the next window boundary)."""
    fleet = _fleet(n=2, boot_s=0.4, max_count=8)
    fleet.estimate_capacity(100.0, n_queries=200)
    overload = 2.5 * fleet.total_capacity()
    t, s = StationaryTraffic(overload).generate(np.random.default_rng(7), 3.0)
    r = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                       window_s=0.2,
                       autoscaler=Autoscaler(sla_ms=100.0,
                                             cooldown_windows=0))
    booted = {}
    checked = 0
    for e in r.lifecycle:
        if e.state is NodeState.BOOTING:
            booted[(e.pool, e.index_in_pool)] = e.t_s
        elif e.state is NodeState.SERVING and (e.pool, e.index_in_pool) \
                in booted:
            assert e.t_s - booted[(e.pool, e.index_in_pool)] >= 0.4 - 1e-9
            checked += 1
    assert checked > 0                       # the overload did scale up


def test_zero_boot_keeps_legacy_instant_serving():
    """boot_s=0 (the default) reproduces the pre-lifecycle behavior:
    a node added at a window boundary serves from that same window."""
    fleet = _fleet(n=1, max_count=2)
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend)
    ctrl.start(0.0)
    fleet.scale("sky", +1)
    serving, _ = ctrl.begin_window(0.5)
    assert len(serving) == 2


# ------------------------------------------------------------ kill/re-route


def test_killed_sim_node_pending_queries_complete_on_survivors():
    times, sizes = _trace(n=400, qps=2000.0)      # deep queues: many pending
    backends = [SimNodeBackend(v) for v in _views(2)]
    faults = FleetFaults(kills=(NodeKill(0.1, "pool", 0),))
    r = drive_fleet(times, sizes, backends, make_router("round_robin"),
                    window_s=0.05, fleet_faults=faults)
    assert r.rerouted > 0
    assert r.dropped == 0                    # every orphan recovered
    dead, survivor = backends
    for rec in dead.completed_records():     # the dead node's history holds
        assert rec.t_done <= 0.1 + 1e-12     # only pre-kill completions
    surv = {rec.index for rec in survivor.completed_records()}
    dead_idx = {rec.index for rec in dead.completed_records()}
    assert surv | dead_idx == set(range(400))
    assert len(surv & dead_idx) == 0
    with pytest.raises(RuntimeError, match="dead"):
        dead.submit(np.array([999]), np.array([5.0]), np.array([4]))


def test_kill_without_reroute_drops_orphans():
    times, sizes = _trace(n=400, qps=2000.0)
    re = drive_fleet(times, sizes,
                     [SimNodeBackend(v) for v in _views(2)],
                     make_router("round_robin"), window_s=0.05,
                     fleet_faults=FleetFaults(
                         kills=(NodeKill(0.1, "pool", 0),)))
    no = drive_fleet(times, sizes,
                     [SimNodeBackend(v) for v in _views(2)],
                     make_router("round_robin"), window_s=0.05,
                     fleet_faults=FleetFaults(
                         kills=(NodeKill(0.1, "pool", 0),), reroute=False))
    assert re.dropped == 0 and re.rerouted > 0
    assert no.rerouted == 0
    assert no.dropped == re.rerouted         # same orphans, now lost


def test_kill_and_restart_cycles_through_boot():
    fleet = _fleet(n=3, boot_s=0.2)
    t, s = _trace(n=500, qps=1000.0)
    faults = FleetFaults(kills=(NodeKill(0.15, "sky", 0,
                                         restart_after_s=0.1),))
    r = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                       window_s=0.05, fleet_faults=faults)
    seq = [e.state for e in r.lifecycle if (e.pool, e.index_in_pool)
           == ("sky", 0)]
    assert seq[0] is NodeState.SERVING       # warm at start
    assert NodeState.DEAD in seq
    i = seq.index(NodeState.DEAD)
    assert seq[i + 1:] == [NodeState.BOOTING, NodeState.SERVING]
    assert r.dropped == 0


def test_kill_all_nodes_drops_tail_without_crashing():
    times, sizes = _trace(n=200, qps=800.0)
    faults = FleetFaults(kills=(NodeKill(0.1, "pool", 0),
                                NodeKill(0.1, "pool", 1)))
    r = drive_fleet(times, sizes, [SimNodeBackend(v) for v in _views(2)],
                    make_router("round_robin"), window_s=0.05,
                    fleet_faults=faults)
    assert r.dropped > 0                     # no survivors to re-route to
    assert r.n_queries + r.dropped == 200
    assert r.n_nodes == 0


def test_fleet_faults_argument_contract():
    times, sizes = _trace(n=50)
    backends = [SimNodeBackend(v) for v in _views(2)]
    with pytest.raises(ValueError, match="window_s"):
        drive_fleet(times, sizes, backends, make_router("round_robin"),
                    fleet_faults=FleetFaults(
                        kills=(NodeKill(0.1, "pool", 0),)))
    with pytest.raises(ValueError, match="restart"):
        drive_fleet(times, sizes, backends, make_router("round_robin"),
                    window_s=0.1,
                    fleet_faults=FleetFaults(kills=(
                        NodeKill(0.1, "pool", 0, restart_after_s=0.1),)))
    from repro.core.simulator import FaultConfig
    with pytest.raises(ValueError, match="fleet_faults"):
        simulate_fleet(times, sizes, _fleet(2), make_router("round_robin"),
                       faults=FaultConfig(straggler_frac=0.1),
                       fleet_faults=FleetFaults())


def test_killed_live_node_pending_queries_complete_on_survivors():
    """The live tier mirrors the sim kill: cancel_pending shuts the
    ServingRuntime down mid-run and surrenders its queued work, which the
    driver re-routes to the surviving node."""
    import time

    import jax.numpy as jnp

    from repro.cluster import (BucketedDeviceModel, LiveNodeBackend,
                               WallClock)
    from repro.serve.runtime import ServingRuntime

    def apply_fn(batch):
        time.sleep(0.02)                 # 20ms service: queues build
        return jnp.asarray(batch["x"]).sum()

    dev = BucketedDeviceModel(np.array([1, 2, 4, 8, 16, 32, 64]),
                              np.full(7, 2e-2))
    spec = NodeSpec(cpu=dev, n_executors=1, batch_size=16,
                    request_overhead_s=0.0)
    clock = WallClock()
    backends = [LiveNodeBackend(
        ServingRuntime(apply_fn, n_workers=1, batch_size=16, max_bucket=64),
        lambda size, mid: {"x": np.ones((size, 4), np.float32)},
        spec=spec, pool="live", index_in_pool=i, weight=100.0, clock=clock,
        own_runtime=True) for i in range(2)]
    times = np.linspace(0.0, 0.2, 40)    # 5ms arrivals vs 20ms service
    sizes = np.full(40, 8, np.int64)
    faults = FleetFaults(kills=(NodeKill(0.1, "live", 0),))
    try:
        r = drive_fleet(times, sizes, backends, make_router("round_robin"),
                        window_s=0.05, fleet_faults=faults, drain_timeout=30)
        assert r.rerouted > 0
        assert r.dropped == 0 and r.n_queries == 40 and r.errors == 0
        with pytest.raises(RuntimeError, match="dead"):
            backends[0].submit(np.array([99]), np.array([0.9]),
                               np.array([4]))
    finally:
        for b in backends:
            b.close()


# ------------------------------------------------------------- draining


def test_draining_node_invisible_to_routers_sim_and_live():
    """The DRAINING router contract: sim and live controllers expose the
    same SERVING list, so any policy makes identical decisions while a
    node drains."""
    times, sizes = _trace(n=120, qps=300.0)
    spec = NodeSpec(cpu=CPU, batch_size=16, n_executors=1,
                    request_overhead_s=0.0)
    sim_ctrl = FleetController(
        backends=[SimNodeBackend(NodeView("live", i, spec, 100.0))
                  for i in range(3)])
    sim_ctrl.start(0.0)

    from repro.cluster import LiveNodeBackend, WallClock
    from repro.serve.runtime import ServingRuntime
    import jax.numpy as jnp

    def apply_fn(batch):
        return jnp.asarray(batch["x"]).sum()

    clock = WallClock()
    live = [LiveNodeBackend(
        ServingRuntime(apply_fn, n_workers=1, batch_size=16, max_bucket=64),
        lambda size, mid: {"x": np.ones((size, 4), np.float32)},
        spec=spec, pool="live", index_in_pool=i, weight=100.0, clock=clock,
        own_runtime=True) for i in range(3)]
    live_ctrl = FleetController(backends=live)
    live_ctrl.start(0.0)
    try:
        sim_ctrl.drain(("live", 1), 0.0)
        live_ctrl.drain(("live", 1), 0.0)
        s_nodes, l_nodes = sim_ctrl.serving(), live_ctrl.serving()
        assert [b.key for b in s_nodes] == [b.key for b in l_nodes] \
            == [("live", 0), ("live", 2)]
        for name in ("round_robin", "least_outstanding", "size_aware",
                     "hetero"):
            a_sim = make_router(name).assign(times, sizes, s_nodes)
            a_live = make_router(name).assign(times, sizes, l_nodes)
            np.testing.assert_array_equal(a_sim, a_live)
        # draining nodes keep advancing (realtime) but are not billed
        assert len(live_ctrl.advance_targets()) == 3
        assert live_ctrl.billable_n == sim_ctrl.billable_n == 2
    finally:
        for b in live:
            b.close()


def test_shrink_then_regrow_revives_draining_node():
    """A pool that shrinks and later regrows must get its node back: the
    ledger naming a DRAINING key again cancels the drain (the backend
    never stopped) instead of stranding it invisible to routers."""
    fleet = _fleet(n=2, max_count=4)
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend)
    ctrl.start(0.0)
    fleet.scale("sky", -1)
    ctrl.reconcile(1.0)
    assert ctrl.states()[("sky", 1)] is NodeState.DRAINING
    serving, _ = ctrl.begin_window(2.0)
    assert len(serving) == 1
    fleet.scale("sky", +1)                   # regrow: same positional key
    serving, _ = ctrl.begin_window(3.0)
    assert len(serving) == 2
    assert ctrl.states()[("sky", 1)] is NodeState.SERVING
    assert ctrl.billable_n == 2


def test_single_window_violation_minutes_counted():
    """A run whose trace fits in one window must still report violation
    time when that window breaches (regression: the diff-of-starts width
    estimate returned 0.0 for len(timeline) == 1)."""
    fleet = _fleet(n=1)
    t, s = _trace(n=600, qps=20000.0)        # far past one node's capacity
    r = simulate_fleet(t, s, fleet, make_router("round_robin"))
    assert len(r.timeline) == 1
    assert r.p95_ms > 100.0
    viol = r.sla_violation_minutes(100.0)
    span_min = (t[-1] - t[0]) / 60.0
    np.testing.assert_allclose(viol, span_min, rtol=1e-6)


def test_autoscaler_shrink_marks_nodes_draining():
    fleet = _fleet(n=6)
    fleet.estimate_capacity(100.0, n_queries=200)
    t, s = StationaryTraffic(10.0).generate(np.random.default_rng(2), 2.0)
    r = simulate_fleet(t, s, fleet, make_router("round_robin"),
                       window_s=0.25,
                       autoscaler=Autoscaler(sla_ms=100.0,
                                             cooldown_windows=0))
    assert any(e.state is NodeState.DRAINING for e in r.lifecycle)
    assert r.dropped == 0                    # drained work still completed


# ------------------------------------------------ ledger-owned identity


def test_kill_written_back_to_fleet_ledger():
    """A kill removes its exact index from pool membership: survivors
    keep their identities, capacity accounting sees the true pool."""
    fleet = _fleet(n=4)
    fleet.estimate_capacity(100.0, n_queries=200)
    cap4 = fleet.total_capacity()
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend,
                           faults=FleetFaults(
                               kills=(NodeKill(0.1, "sky", 1),)))
    ctrl.start(0.0)
    ctrl.begin_window(0.0)
    serving, _ = ctrl.begin_window(0.1)
    assert fleet.pool("sky").count == 3
    assert fleet.pool("sky").member_ids() == [0, 2, 3]
    assert [b.index_in_pool for b in serving] == [0, 2, 3]
    np.testing.assert_allclose(fleet.total_capacity(), 0.75 * cap4)


def test_regrowth_reuses_dead_index():
    """Scaling up after a kill refills the vacated slot (lowest free
    index) with a fresh cold node rather than minting ever-higher ids."""
    fleet = _fleet(n=3, boot_s=0.3, max_count=4)
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend,
                           faults=FleetFaults(
                               kills=(NodeKill(0.1, "sky", 1),)))
    ctrl.start(0.0)
    ctrl.begin_window(0.1)                   # kill lands: members [0, 2]
    assert fleet.pool("sky").member_ids() == [0, 2]
    assert fleet.scale("sky", +1) == 1
    assert fleet.pool("sky").member_ids() == [0, 1, 2]
    serving, _ = ctrl.begin_window(0.2)
    assert ctrl.states()[("sky", 1)] is NodeState.BOOTING   # fresh, cold
    assert len(serving) == 2
    serving, _ = ctrl.begin_window(0.5)      # 0.2 + 0.3 boot elapsed
    assert [b.index_in_pool for b in serving] == [0, 1, 2]


def test_restart_restores_ledger_membership():
    fleet = _fleet(n=3, boot_s=0.2)
    t, s = _trace(n=500, qps=1000.0)
    faults = FleetFaults(kills=(NodeKill(0.15, "sky", 0,
                                         restart_after_s=0.1),))
    r = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                       window_s=0.05, fleet_faults=faults)
    assert r.dropped == 0
    # the caller's ledger is untouched (kill runs mutate a copy) …
    assert fleet.pool("sky").count == 3
    # … and the run's own per-pool count reflects the restored membership
    assert r.per_pool["sky"].n_nodes == 3


def test_simulate_fleet_kills_do_not_mutate_caller_fleet():
    fleet = _fleet(n=4)
    t, s = _trace(n=200, qps=800.0)
    r = simulate_fleet(t, s, fleet, make_router("round_robin"),
                       window_s=0.05,
                       fleet_faults=FleetFaults(
                           kills=(NodeKill(0.1, "sky", 0),)))
    assert fleet.pool("sky").count == 4      # back-to-back runs stay fair
    assert fleet.pool("sky").member_ids() == [0, 1, 2, 3]
    assert r.per_pool["sky"].n_nodes == 3    # the run itself saw the kill


def test_kill_plan_naming_unknown_node_is_inert():
    """A typo'd kill — bogus index or unknown pool — even with a restart
    schedule must neither crash the run nor restore/materialize a
    phantom node the fleet never had."""
    fleet = _fleet(n=2)
    t, s = _trace(n=100, qps=400.0)
    faults = FleetFaults(kills=(
        NodeKill(0.05, "sky", 99, restart_after_s=0.05),
        NodeKill(0.05, "nope", 0, restart_after_s=0.05)))
    r = simulate_fleet(t, s, fleet, make_router("round_robin"),
                       window_s=0.02, fleet_faults=faults)
    assert r.dropped == 0 and r.rerouted == 0
    assert r.n_nodes == 2
    assert fleet.pool("sky").member_ids() == [0, 1]
    assert all(e.pool == "sky" and e.index_in_pool in (0, 1)
               for e in r.lifecycle)


def test_drive_fleet_kills_do_not_mutate_caller_fleet_directly():
    """The copy guard lives in drive_fleet itself, not only the
    simulate_fleet wrapper — direct fleet-mode callers (e.g. a remote
    backend factory) reuse their ledger across runs too."""
    fleet = _fleet(n=3)
    t, s = _trace(n=100, qps=400.0)
    r = drive_fleet(t, s, None, make_router("round_robin"), window_s=0.05,
                    fleet=fleet, factory=SimNodeBackend,
                    fleet_faults=FleetFaults(
                        kills=(NodeKill(0.05, "sky", 0),)))
    assert r.per_pool["sky"].n_nodes == 2    # the run saw the kill
    assert fleet.pool("sky").count == 3      # the caller's ledger did not
    assert fleet.pool("sky").member_ids() == [0, 1, 2]


def test_autoscaler_utilization_trigger_sees_post_kill_pool():
    """Killing half the pool under moderate load pushes offered/capacity
    over the utilization bar *because the ledger shrank* — the autoscaler
    reacts to the kill without waiting for the p95 backstop."""
    fleet = _fleet(n=4, max_count=8)
    fleet.estimate_capacity(100.0, n_queries=200)
    rate = 0.55 * fleet.total_capacity()     # calm before the kill
    t, s = StationaryTraffic(rate).generate(np.random.default_rng(5), 3.0)
    faults = FleetFaults(kills=(NodeKill(1.0, "sky", 0),
                                NodeKill(1.0, "sky", 1)))
    # up_at=10 parks the p95 backstop out of reach: the post-kill queueing
    # would fire it in the same window, and this test is specifically
    # about the *capacity* signal (pre-writeback, util read 0.55 forever)
    r = simulate_fleet(t, s, fleet, make_router("round_robin"),
                       window_s=0.25, fleet_faults=faults,
                       autoscaler=Autoscaler(sla_ms=100.0, up_at=10.0,
                                             cooldown_windows=0))
    grow = [e for e in r.events if e.delta > 0]
    assert grow and all(e.t_s >= 1.0 for e in grow)
    assert grow[0].reason == "util"          # capacity, not the backstop


# ------------------------------------------------- take_new_records cursor


def test_take_new_records_returns_each_completion_once():
    times, sizes = _trace(n=60)
    b = SimNodeBackend(_views(1)[0])
    b.submit(np.arange(30), times[:30], sizes[:30])
    first = b.take_new_records()
    assert sorted(r.index for r in first) == list(range(30))
    assert b.take_new_records() == []
    b.submit(np.arange(30, 60), times[30:], sizes[30:])
    second = b.take_new_records()
    assert sorted(r.index for r in second) == list(range(30, 60))
    # full history remains available alongside the cursor
    assert len(b.completed_records()) == 60


# --------------------------------------------------- predictive autoscaler


def test_scaling_events_carry_trigger_reason():
    fleet = _fleet(n=2, max_count=10)
    fleet.estimate_capacity(100.0, n_queries=200)
    overload = 2.0 * fleet.total_capacity()
    t, s = StationaryTraffic(overload).generate(np.random.default_rng(7), 2.0)
    r = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                       window_s=0.2,
                       autoscaler=Autoscaler(sla_ms=100.0,
                                             cooldown_windows=0))
    assert len(r.events) > 0
    assert all(e.reason in ("p95", "util") for e in r.events)


def test_predictive_scales_ahead_of_known_ramp():
    """With the scenario curve in hand the predictive scaler fires
    'forecast' events while the reactive one is still comfortable."""
    fleet = _fleet(n=2, boot_s=0.5, max_count=12)
    fleet.estimate_capacity(100.0, n_queries=200)
    base = 0.5 * fleet.total_capacity()
    tr = DiurnalTraffic(base_qps=base, amplitude=0.9, period_s=8.0)
    t, s = tr.generate(np.random.default_rng(3), 8.0)
    scaler = PredictiveAutoscaler(sla_ms=100.0, cooldown_windows=0,
                                  traffic=tr, lead_s=1.0)
    r = simulate_fleet(t, s, fleet, make_router("least_outstanding"),
                       window_s=0.5, autoscaler=scaler)
    assert any(e.reason == "forecast" for e in r.events)


def test_predictive_ewma_fallback_tracks_a_ramp():
    """Without a known curve the Holt-trend forecast still extrapolates a
    steady ramp upward (forecast > last observation)."""
    sc = PredictiveAutoscaler(sla_ms=100.0, lead_s=2.0)
    fc = 0.0
    for i, t in enumerate(np.arange(0.0, 10.0, 0.5)):
        fc = sc.forecast(t, offered_qps=100.0 + 50.0 * t)
    assert fc > 100.0 + 50.0 * 9.5           # above the last observation


# --------------------------------------------------- traffic calibration


@pytest.mark.parametrize("traffic", [
    DiurnalTraffic(base_qps=300.0, amplitude=0.7, period_s=10.0),
    MultiTenantTraffic(tenants=(
        ("a", DiurnalTraffic(base_qps=150.0, amplitude=0.5, period_s=10.0),
         PRODUCTION),
        ("b", StationaryTraffic(100.0), SizeDist("fixed", mean=4.0)),
    )),
], ids=["diurnal", "multi_tenant"])
def test_expected_queries_matches_empirical_thinning(traffic):
    """The closed-form/trapezoid ∫rate is what the predictive scaler and
    the node-hour budgets trust — it must match the thinned-Poisson
    generator empirically, not just analytically."""
    horizon = 10.0
    expect = traffic.expected_queries(horizon)
    counts = [len(traffic.generate(np.random.default_rng(seed), horizon)[0])
              for seed in range(30)]
    mean = float(np.mean(counts))
    # 30-seed mean: sigma_mean = sqrt(expect/30); allow 4 sigma
    assert abs(mean - expect) < 4 * np.sqrt(expect / 30), (mean, expect)


# ----------------------------------------------------------- search cap


def test_cluster_max_qps_explicit_hi_is_bracket_not_ceiling():
    """An explicit hi below the true capacity must not silently cap the
    answer — the doubling bracket (bounded by the same cap= guard as the
    hint path) climbs past it."""
    fleet = _fleet(n=2)
    fleet.estimate_capacity(100.0, n_queries=200)
    cold = cluster_max_qps(fleet, make_router("round_robin"), 100.0,
                           n_queries=300, iters=7)
    assert cold > 0
    low_hi = cluster_max_qps(fleet, make_router("round_robin"), 100.0,
                             n_queries=300, iters=7, hi=cold * 0.3)
    assert low_hi >= 0.9 * cold, (low_hi, cold)


# ----------------------------------------------------------- self-healing


def test_self_heal_restarts_killed_node_through_boot():
    """A kill with no restart schedule, under a SelfHealPolicy: the node
    auto-restarts through BOOTING and serves again; without the policy
    (the ablation) it stays dead."""
    t, s = _trace(n=300, qps=800.0)
    kills = FleetFaults(kills=(NodeKill(0.1, "sky", 0),))
    healed = simulate_fleet(t, s, _fleet(n=2, boot_s=0.1),
                            make_router("round_robin"), window_s=0.05,
                            fleet_faults=kills,
                            self_heal=SelfHealPolicy(backoff_s=0.0))
    seq = [e.state for e in healed.lifecycle
           if (e.pool, e.index_in_pool) == ("sky", 0)]
    i = seq.index(NodeState.DEAD)
    assert seq[i + 1:i + 3] == [NodeState.BOOTING, NodeState.SERVING]
    assert healed.dropped == 0
    ablation = simulate_fleet(t, s, _fleet(n=2, boot_s=0.1),
                              make_router("round_robin"), window_s=0.05,
                              fleet_faults=kills)
    seq = [e.state for e in ablation.lifecycle
           if (e.pool, e.index_in_pool) == ("sky", 0)]
    assert seq[-1] is NodeState.DEAD         # no policy: stays dead
    assert ablation.n_nodes == 1


def test_self_heal_budget_exhausted_stays_dead():
    """Crash-loop protection: a node that keeps dying is restarted at
    most max_restarts times, then left dead."""
    t, s = _trace(n=300, qps=800.0)
    kills = FleetFaults(kills=(NodeKill(0.05, "sky", 0),
                               NodeKill(0.15, "sky", 0),
                               NodeKill(0.25, "sky", 0)))
    r = simulate_fleet(t, s, _fleet(n=2), make_router("round_robin"),
                       window_s=0.05, fleet_faults=kills,
                       self_heal=SelfHealPolicy(max_restarts=1,
                                                backoff_s=0.0))
    seq = [e.state for e in r.lifecycle
           if (e.pool, e.index_in_pool) == ("sky", 0)]
    assert seq.count(NodeState.DEAD) == 2    # original + one revival died
    assert seq[-1] is NodeState.DEAD
    assert r.n_nodes == 1


def test_self_heal_backoff_delays_restart():
    fleet = _fleet(n=2)
    ctrl = FleetController(
        fleet=fleet, factory=SimNodeBackend,
        faults=FleetFaults(kills=(NodeKill(0.1, "sky", 0),)),
        heal=SelfHealPolicy(backoff_s=0.2))
    ctrl.start(0.0)
    serving, _ = ctrl.begin_window(0.1)      # kill lands; due at 0.1+0.2
    assert len(serving) == 1
    serving, _ = ctrl.begin_window(0.2)
    assert len(serving) == 1                 # still backing off
    serving, _ = ctrl.begin_window(0.3)
    assert len(serving) == 2                 # revived


class _DiesOnSubmit(SimNodeBackend):
    """A sim node whose submit starts raising BackendDied at ``die_at`` —
    the driver's mid-window unplanned-death path."""

    def __init__(self, view, die_at=np.inf):
        super().__init__(view)
        self.die_at = die_at
        self._dead_flag = False

    def submit(self, idx, times, sizes, model_ids=None):
        if len(times) and float(times[-1]) >= self.die_at:
            self._dead_flag = True
            raise BackendDied(f"node {self.key}: died mid-submit")
        return super().submit(idx, times, sizes, model_ids)

    def dead(self) -> bool:
        return self._dead_flag


def test_mid_submit_death_rerouted_to_survivor():
    """A backend raising BackendDied inside submit is retired through the
    controller and its queries — the failed batch plus everything it had
    accepted — land on the survivor, not the floor."""
    times, sizes = _trace(n=300, qps=1500.0)
    views = _views(2)
    backends = [_DiesOnSubmit(views[0], die_at=0.1), SimNodeBackend(views[1])]
    r = drive_fleet(times, sizes, backends, make_router("round_robin"),
                    window_s=0.05)
    assert r.dropped == 0 and r.rerouted > 0
    assert any(e.state is NodeState.DEAD and e.index_in_pool == 0
               for e in r.lifecycle)
    surv = {rec.index for rec in backends[1].completed_records()}
    dead_idx = {rec.index for rec in backends[0].completed_records()}
    assert surv | dead_idx == set(range(300))


class _Flaky(SimNodeBackend):
    """Transport-degraded stand-in: suspect flag + a controllable verify
    verdict (the SUSPECT → verify → reinstate/retire path)."""

    def __init__(self, view):
        super().__init__(view)
        self.suspect = False
        self.verify_ok = True

    def verify(self, timeout: float = 5.0) -> bool:
        return self.verify_ok


def test_suspect_node_verified_and_reinstated():
    views = _views(2)
    backends = [_Flaky(views[0]), SimNodeBackend(views[1])]
    ctrl = FleetController(backends=backends)
    ctrl.start(0.0)
    backends[0].suspect = True               # transport hiccup, false alarm
    serving, orphans = ctrl.begin_window(0.1)
    assert len(serving) == 2 and not orphans
    states = [e.state for e in ctrl.events if e.index_in_pool == 0]
    assert states[-2:] == [NodeState.SUSPECT, NodeState.SERVING]
    backends[0].suspect = True
    backends[0].verify_ok = False            # verify fails: really gone
    serving, _ = ctrl.begin_window(0.2)
    assert len(serving) == 1
    states = [e.state for e in ctrl.events if e.index_in_pool == 0]
    assert states[-2:] == [NodeState.SUSPECT, NodeState.DEAD]


def test_terminate_idle_closes_draining_node():
    """Under terminate_idle, a DRAINING node whose work is done is closed
    mid-run (DEAD) instead of lingering to the end; without the policy it
    lingers (the shrink-then-regrow revival contract depends on that)."""
    fleet = _fleet(n=2, max_count=4)
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend,
                           heal=SelfHealPolicy(terminate_idle=True))
    ctrl.start(0.0)
    fleet.scale("sky", -1)
    ctrl.reconcile(0.5)
    assert ctrl.states()[("sky", 1)] is NodeState.DRAINING
    ctrl.begin_window(1.0)                   # no accepted work: idle now
    assert ctrl.states()[("sky", 1)] is NodeState.DEAD
    assert ("sky", 1) not in ctrl._nodes     # actually retired, not lingering
    # regrowth after termination materializes a *fresh* node (cold boot),
    # not a revived ghost
    fleet.scale("sky", +1)
    serving, _ = ctrl.begin_window(2.0)
    assert len(serving) == 2


def test_draining_node_with_pending_work_not_terminated():
    times, sizes = _trace(n=200, qps=500.0)
    fleet = _fleet(n=2, max_count=4)
    ctrl = FleetController(fleet=fleet, factory=SimNodeBackend,
                           heal=SelfHealPolicy(terminate_idle=True))
    ctrl.start(0.0)
    serving, _ = ctrl.begin_window(0.0)
    # load node 1 with work completing well past the drain point
    serving[1].submit(np.arange(100), times[:100], np.full(100, 256))
    fleet.scale("sky", -1)
    ctrl.reconcile(0.1)
    ctrl.begin_window(0.15)                  # still finishing: not closed
    assert ctrl.states()[("sky", 1)] is NodeState.DRAINING
    ctrl.begin_window(1e9)                   # all work long done
    assert ctrl.states()[("sky", 1)] is NodeState.DEAD


def test_timeline_carries_driver_stall_column():
    """Fast-path timeline rows grow a ctl_s column (wall seconds of
    driver control work per window) read via driver_stall_s()."""
    times, sizes = _trace(n=200, qps=800.0)
    r = drive_fleet(times, sizes, [SimNodeBackend(v) for v in _views(2)],
                    make_router("round_robin"), window_s=0.05)
    stalls = r.driver_stall_s()
    assert len(stalls) == len(r.timeline) > 1
    assert all(x >= 0.0 for x in stalls)
    for row in r.timeline:                   # existing columns unmoved
        assert len(row) == 6 and row[4] > 0


def test_chaos_plan_schedule_and_kill_compat():
    """ChaosPlan is a FleetFaults superset: kills flow through the same
    controller path; hangs+garbles come out of injections() in trace
    order; slow starts answer by node key."""
    from repro.cluster import ChaosPlan, FrameGarble, RpcHang, SlowStart
    from repro.cluster.chaos import crash_storm

    plan = ChaosPlan(
        kills=crash_storm(0.3, "sky", [0, 2]),
        hangs=(RpcHang(0.4, "sky", 1, hang_s=2.0),),
        garbles=(FrameGarble(0.2, "sky", 1),
                 FrameGarble(0.5, "sky", 0, drop=True)),
        slow_starts=(SlowStart("sky", 2, extra_s=1.5),))
    assert isinstance(plan, FleetFaults)
    assert [k.key for k in plan.kills] == [("sky", 0), ("sky", 2)]
    inj = plan.injections()
    assert [e.t_s for e in inj] == [0.2, 0.4, 0.5]
    assert [e.mode for e in inj] == ["garble", "hang", "drop"]
    assert plan.slow_start_s("sky", 2) == 1.5
    assert plan.slow_start_s("sky", 0) == 0.0
    # a ChaosPlan drives the sim engine too: kills work, injections are
    # silently ignored by backends without a transport to fault
    t, s = _trace(n=200, qps=800.0)
    r = simulate_fleet(t, s, _fleet(n=3), make_router("round_robin"),
                      window_s=0.05, fleet_faults=plan,
                      self_heal=SelfHealPolicy(backoff_s=0.0))
    assert r.dropped == 0
