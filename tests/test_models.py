"""Per-architecture smoke tests (reduced configs, one forward/train step on
CPU, asserting shapes + finite outputs) — all 10 assigned archs + the 8
DeepRecInfra paper models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import synthetic as syn
from repro.models import gnn, lm, recsys

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def nprng():
    return np.random.default_rng(0)


RECSYS_ARCHS = configs.list_archs("recsys")
LM_ARCHS = configs.list_archs("lm")


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_forward_and_grad(arch, nprng=None):
    nprng = np.random.default_rng(0)
    cfg = configs.get(arch).smoke_config
    params = recsys.init(KEY, cfg)
    batch = syn.recsys_batch(nprng, cfg, 8)
    out = recsys.forward(params, cfg, batch)
    expected = (8,) if cfg.n_tasks == 1 else (8, cfg.n_tasks)
    assert out.shape == expected
    assert np.isfinite(np.asarray(out)).all()
    loss, grads = jax.value_and_grad(
        lambda p: recsys.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all()
               for g in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("arch", ["mind", "bert4rec"])
def test_recsys_retrieval_head(arch):
    nprng = np.random.default_rng(0)
    cfg = configs.get(arch).smoke_config
    params = recsys.init(KEY, cfg)
    batch = syn.recsys_batch(nprng, cfg, 2, n_candidates=64, with_label=False)
    scores = recsys.score_candidates(params, cfg, batch)
    assert scores.shape == (2, 64)
    assert np.isfinite(np.asarray(scores)).all()


def test_recsys_bulk_forward_matches_direct():
    nprng = np.random.default_rng(0)
    cfg = configs.get("xdeepfm").smoke_config
    params = recsys.init(KEY, cfg)
    batch = syn.recsys_batch(nprng, cfg, 32, with_label=False)
    direct = recsys.forward(params, cfg, batch)
    chunked = recsys.bulk_forward(params, cfg, batch, chunk=8)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    nprng = np.random.default_rng(0)
    cfg = configs.get(arch).smoke_config
    params = lm.init(KEY, cfg)
    batch = syn.lm_batch(nprng, cfg, 2, 16)
    loss, grads = jax.value_and_grad(lambda p: lm.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    logits, caches = lm.prefill(params, cfg, batch["tokens"][:, :8], 16)
    assert logits.shape == (2, cfg.vocab)
    nxt, caches = lm.decode_step(params, cfg, batch["tokens"][:, 8], caches)
    assert nxt.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(nxt)).all()


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "granite-moe-1b-a400m"])
def test_lm_scan_equals_unrolled(arch):
    nprng = np.random.default_rng(0)
    cfg = configs.get(arch).smoke_config
    cfg_scan = dataclasses.replace(cfg, scan_layers=True)
    params_u = lm.init(KEY, cfg)
    params_s = lm.init(KEY, cfg_scan)
    batch = syn.lm_batch(nprng, cfg, 2, 16)
    lu = lm.loss_fn(params_u, cfg, batch)
    ls = lm.loss_fn(params_s, cfg_scan, batch)
    np.testing.assert_allclose(float(lu), float(ls), rtol=1e-5)


def test_lm_prefill_decode_consistent_with_forward():
    """prefill(t[:k]) + decode(t[k]) logits == forward(t[:k+1]) last logits."""
    cfg = configs.get("qwen2-0.5b").smoke_config
    params = lm.init(KEY, cfg)
    nprng = np.random.default_rng(0)
    batch = syn.lm_batch(nprng, cfg, 2, 8)
    toks = batch["tokens"]
    logits_full, _ = lm.forward(params, cfg, toks)
    logits_pre, caches = lm.prefill(params, cfg, toks[:, :7], 8)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, 6]),
                               rtol=5e-4, atol=5e-4)
    logits_dec, _ = lm.decode_step(params, cfg, toks[:, 7], caches)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, 7]),
                               rtol=5e-4, atol=5e-4)


def test_lm_param_count_analytics():
    cfg = configs.get("qwen2-0.5b").smoke_config
    params = lm.init(KEY, cfg)
    from repro.utils import param_count
    assert abs(param_count(params) - cfg.param_count) / cfg.param_count < 0.02


# ---------------------------------------------------------------- gnn


def test_gcn_full_batch_smoke():
    cfg = configs.get("gcn-cora").smoke_config
    params = gnn.init(KEY, cfg)
    nprng = np.random.default_rng(0)
    g = syn.random_graph(nprng, 60, 240, cfg.d_feat, cfg.n_classes)
    logits = gnn.forward(params, cfg, g["x"], g["edge_index"])
    assert logits.shape == (60, cfg.n_classes)
    loss, grads = jax.value_and_grad(lambda p: gnn.loss_fn(p, cfg, g))(params)
    assert np.isfinite(float(loss))


def test_gcn_minibatch_sampler_and_blocks():
    cfg = configs.get("gcn-cora").smoke_config
    params = gnn.init(KEY, cfg)
    nprng = np.random.default_rng(0)
    g = syn.random_graph(nprng, 100, 500, cfg.d_feat, cfg.n_classes)
    indptr, indices = syn.graph_to_csr(100, np.asarray(g["edge_index"]))
    blocks, input_nodes = gnn.sample_neighbors(indptr, indices,
                                               np.arange(16), [4, 3], nprng)
    # fanout bound holds per block
    for (ei, n_src, n_dst), fan in zip(blocks, [3, 4]):
        per_dst = np.bincount(np.asarray(ei[1]), minlength=n_dst)
        assert per_dst.max() <= fan
    x_in = jnp.asarray(np.asarray(g["x"])[input_nodes])
    out = gnn.forward_blocks(params, cfg, x_in, blocks)
    assert out.shape == (16, cfg.n_classes)


def test_gcn_molecule_batched():
    cfg = configs.get("gcn-cora").smoke_config
    params = gnn.init(KEY, cfg)
    nprng = np.random.default_rng(0)
    mb = syn.molecule_batch(nprng, 8, 10, 20, cfg.d_feat, cfg.n_classes)
    loss = gnn.graph_loss_fn(params, cfg, mb)
    assert np.isfinite(float(loss))


def test_gcn_aggregation_averages_neighbors():
    """A node whose neighbors all carry feature v aggregates toward v."""
    cfg = dataclasses.replace(configs.get("gcn-cora").smoke_config,
                              n_layers=1, d_feat=4, n_classes=4)
    x = jnp.zeros((4, 4)).at[1:, :].set(1.0)
    ei = jnp.array([[1, 2, 3], [0, 0, 0]])          # 1,2,3 → 0
    agg = gnn.gcn_aggregate(x, ei, 4, norm="mean")
    assert float(agg[0, 0]) > 0.7                   # pulled toward neighbors
