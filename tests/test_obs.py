"""Observability tier: quantile sketch, metrics registry, per-query
spans, tail-latency attribution, and exporters.

Sketch/registry tests are pure numpy; engine tests drive small traces
through the sim and live backends (canned device curves, no calibration)
and one scripted remote fault, mirroring the chaos-suite sizing so the
tier-1 wall-clock stays bounded.
"""
import json
import os

import numpy as np
import pytest

from repro.cluster import (BucketedDeviceModel, ChaosPlan, Fleet, FleetFaults,
                           NodeKill, NodeSpec, Pool, RpcHang, SimNodeBackend,
                           WallClock, drive_fleet, live_node, make_router,
                           sim_backends)
from repro.cluster.fleet import NodeView
from repro.obs import (COMPONENTS, STAGES, FleetTimeline, Histogram,
                       MetricsRegistry, QuantileSketch, SpanTable,
                       observe_fanout, run_lines, to_prometheus, write_jsonl)
from repro.obs.dump import summarize

pytestmark = pytest.mark.cluster

REL_ERR = 0.02


def _canned(service_s: float) -> BucketedDeviceModel:
    return BucketedDeviceModel(np.array([1, 2, 4, 8, 16, 32, 64]),
                               np.full(7, service_s))


def _trace(n: int, horizon: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    times = np.sort(rng.uniform(0.0, horizon, n))
    sizes = rng.integers(1, 17, n).astype(np.int64)
    return times, sizes


def _sim_result(n=600, horizon=1.0, count=3, telemetry=True, faults=None,
                window_s=0.1, service_s=2e-4):
    times, sizes = _trace(n, horizon)
    spec = NodeSpec(cpu=_canned(service_s), n_executors=2, batch_size=16,
                    request_overhead_s=0.0)
    fleet = Fleet([Pool("cpu", spec, count=count)])
    return drive_fleet(times, sizes, sim_backends(fleet.node_views()),
                       make_router("round_robin"), window_s=window_s,
                       telemetry=telemetry, fleet_faults=faults)


# ------------------------------------------------------- quantile sketch


@pytest.mark.parametrize("values", [
    # 25/75 mix so the tested percentiles land inside a mode — rank-based
    # sketches legitimately disagree with numpy's interpolation *between*
    # modes, which is not an accuracy question
    np.concatenate([np.random.default_rng(1).normal(10.0, 1.0, 5_000),
                    np.random.default_rng(2).normal(100.0, 5.0, 15_000)]),
    np.random.default_rng(3).lognormal(0.0, 1.5, 20_000),
], ids=["bimodal", "heavy_tail"])
def test_sketch_accuracy_vs_numpy(values):
    values = np.abs(values)
    s = QuantileSketch(REL_ERR)
    s.observe_many(values)
    for p in (50.0, 90.0, 95.0, 99.0):
        exact = float(np.percentile(values, p))
        got = s.percentile(p)
        assert abs(got - exact) <= 0.05 * exact, (p, got, exact)
    assert s.n == len(values)
    assert np.isclose(s.mean, values.mean(), rtol=1e-9)
    assert s.vmin == values.min() and s.vmax == values.max()


def test_sketch_merge_associative_and_matches_single():
    rng = np.random.default_rng(5)
    parts = [rng.lognormal(0.0, 1.0, 4_000),
             rng.uniform(50.0, 500.0, 3_000),
             rng.normal(3.0, 0.5, 2_000)]
    sketches = []
    for p in parts:
        s = QuantileSketch(REL_ERR)
        s.observe_many(p)
        sketches.append(s)
    a, b, c = (s.copy() for s in sketches)
    left = a.merge(b).merge(c)                       # (A + B) + C
    a2, b2, c2 = (s.copy() for s in sketches)
    right = a2.merge(b2.merge(c2))                   # A + (B + C)
    single = QuantileSketch(REL_ERR)
    single.observe_many(np.concatenate(parts))
    qs = (0.01, 0.25, 0.5, 0.9, 0.99, 1.0)
    assert left.quantiles(qs) == right.quantiles(qs)  # exactly — not approx
    assert left.quantiles(qs) == single.quantiles(qs)
    assert left.counts == right.counts == single.counts
    assert left.n == right.n == single.n == sum(len(p) for p in parts)


def test_sketch_merge_rejects_mismatched_rel_err():
    with pytest.raises(ValueError, match="rel_err"):
        QuantileSketch(0.02).merge(QuantileSketch(0.01))


def test_sketch_edge_cases():
    s = QuantileSketch(REL_ERR)
    assert np.isnan(s.quantile(0.5)) and np.isnan(s.mean)   # empty

    s.observe(3.7)                                   # one sample is exact
    assert s.quantile(0.0) == s.quantile(0.5) == s.quantile(1.0) == 3.7

    z = QuantileSketch(REL_ERR)
    z.observe_many([0.0, -1.0, 2.0])                 # zero bucket
    assert z.n == 3 and z.n_zero == 2
    assert z.quantile(0.1) == 0.0                    # non-positive report 0
    assert z.vmin == -1.0 and z.vmax == 2.0

    nan = QuantileSketch(REL_ERR)
    nan.observe(float("nan"))
    nan.observe_many([np.nan, 5.0, np.nan])          # NaNs dropped, not kept
    assert nan.n == 1 and nan.quantile(0.5) == 5.0

    with pytest.raises(ValueError):
        s.quantile(1.5)
    with pytest.raises(ValueError):
        QuantileSketch(1.5)


def test_sketch_scalar_and_batch_paths_agree():
    rng = np.random.default_rng(11)
    vals = np.concatenate([rng.lognormal(1.0, 1.0, 500), [0.0, 7.5]])
    one = QuantileSketch(REL_ERR)
    for v in vals:
        one.observe(v)
    many = QuantileSketch(REL_ERR)
    many.observe_many(vals)
    assert one.counts == many.counts
    assert (one.n, one.n_zero, one.vmin, one.vmax) == \
        (many.n, many.n_zero, many.vmin, many.vmax)
    assert np.isclose(one.total, many.total)


def test_sketch_count_above():
    s = QuantileSketch(REL_ERR)
    assert s.count_above(1.0) == 0                   # empty
    vals = np.array([0.0, -2.0, 0.5, 10.0, 100.0, 1000.0])
    s.observe_many(vals)
    assert s.count_above(-5.0) == len(vals)          # below vmin
    assert s.count_above(1e6) == 0                   # above vmax
    assert s.count_above(0.0) == 4                   # non-positives excluded
    # interior thresholds are bucket-resolution: exact within rel_err mass
    for t, exact in ((5.0, 3), (50.0, 2), (500.0, 1)):
        assert s.count_above(t) == exact, t
    rng = np.random.default_rng(17)
    many = rng.lognormal(0.0, 2.0, 20_000)
    m = QuantileSketch(REL_ERR)
    m.observe_many(many)
    for t in (0.1, 1.0, 10.0):
        exact = int((many > t).sum())
        assert abs(m.count_above(t) - exact) <= 0.03 * len(many), t


def test_sketch_copy_and_reset_are_independent():
    s = QuantileSketch(REL_ERR)
    s.observe_many([1.0, 2.0, 4.0])
    c = s.copy()
    c.observe(1000.0)
    assert s.n == 3 and c.n == 4 and s.vmax == 4.0
    s.reset()
    assert s.n == 0 and np.isnan(s.quantile(0.5))
    s.observe(9.0)                                   # usable after reset
    assert s.quantile(0.5) == 9.0 and c.n == 4


# ------------------------------------------------------ metrics registry


def test_registry_snapshot_window_semantics():
    reg = MetricsRegistry()
    reg.counter("served").inc(5)
    reg.histogram("lat_ms", node="a").observe_many([1.0, 2.0, 3.0])
    s1 = reg.snapshot()
    assert s1["served"] == 5.0
    assert s1['lat_ms{node="a"}.count'] == 3.0
    assert 'lat_ms{node="a"}.p50' in s1 and 'lat_ms{node="a"}.mean' in s1
    # window reset: a second snapshot with no new samples reports empty,
    # while the counter stays cumulative and the total sketch keeps all
    reg.counter("served").inc(2)
    s2 = reg.snapshot()
    assert s2["served"] == 7.0
    assert s2['lat_ms{node="a"}.count'] == 0.0
    assert 'lat_ms{node="a"}.p50' not in s2
    assert reg.histogram("lat_ms", node="a").total.n == 3


def test_timeline_capture_is_lazy_and_window_scoped():
    reg = MetricsRegistry()
    tl = FleetTimeline()
    reg.histogram("x").observe_many([1.0] * 8)
    tl.snapshot(reg, 0.0, 1.0, extra={"qps": 8.0})
    reg.histogram("x").observe_many([100.0] * 8)
    tl.snapshot(reg, 1.0, 1.0)
    assert len(tl) == 2
    # each window rendered only what it captured (the boundary stole the
    # window sketch; later samples cannot leak backwards)
    assert tl.windows[0].metrics["x.p50"] == pytest.approx(1.0, rel=0.05)
    assert tl.windows[1].metrics["x.p50"] == pytest.approx(100.0, rel=0.05)
    assert tl.windows[0].extra == {"qps": 8.0}
    assert tl.series("x.count") == [(0.0, 8.0), (1.0, 8.0)]


def test_observe_grouped_matches_per_group_observe():
    rng = np.random.default_rng(7)
    groups = rng.integers(0, 3, 400)
    values = rng.lognormal(0.0, 1.0, 400)
    values[10] = np.nan                              # dropped everywhere
    values[20] = 0.0                                 # zero bucket
    grouped = MetricsRegistry()
    grouped.observe_grouped("m_ms", "model", groups, values)
    direct = MetricsRegistry()
    for g in np.unique(groups):
        mask = (groups == g) & ~np.isnan(values)
        direct.histogram("m_ms", model=str(g)).observe_many(values[mask])
    for g in np.unique(groups):
        hg = grouped.histogram("m_ms", model=str(g)).total
        hd = direct.histogram("m_ms", model=str(g)).total
        assert hg.counts == hd.counts
        assert (hg.n, hg.n_zero, hg.vmin, hg.vmax) == \
            (hd.n, hd.n_zero, hd.vmin, hd.vmax)
        assert np.isclose(hg.total, hd.total)


def test_observe_fanout_matches_separate_observes():
    vals = np.random.default_rng(9).lognormal(0.0, 1.0, 300)
    a, b = Histogram(), Histogram()
    observe_fanout(vals, a, b)
    ref = Histogram()
    ref.observe_many(vals)
    for h in (a, b):
        assert h.total.counts == ref.total.counts
        assert h.window.counts == ref.window.counts
        assert h.total.n == len(vals)


def test_merged_histogram_is_fleet_rollup():
    reg = MetricsRegistry()
    reg.histogram("lat", node="a").observe_many([1.0, 1.0])
    reg.histogram("lat", node="b").observe_many([100.0, 100.0])
    m = reg.merged_histogram("lat")
    assert m.n == 4
    assert m.quantile(0.25) == pytest.approx(1.0, rel=0.05)
    assert m.quantile(1.0) == pytest.approx(100.0, rel=0.05)


def test_prometheus_exposition_format():
    reg = MetricsRegistry()
    reg.counter("queries_completed").inc(41)
    reg.gauge("serving_nodes").set(3)
    reg.histogram("lat_ms", node="cpu[0]").observe_many([1.0, 2.0, 10.0])
    text = to_prometheus(reg)
    assert "# HELP queries_completed" in text
    assert "# TYPE queries_completed counter" in text
    assert "queries_completed 41" in text
    assert "# TYPE serving_nodes gauge" in text
    assert "# TYPE lat_ms summary" in text
    assert 'lat_ms_count{node="cpu[0]"} 3' in text
    assert 'quantile="0.95"' in text


def test_prometheus_golden_exposition():
    """Byte-exact golden rendering: HELP before TYPE once per family,
    sorted label order regardless of insertion order, escaped label
    values.  Single-observation histograms make the summary quantiles
    exact, so the whole exposition is deterministic."""
    reg = MetricsRegistry()
    reg.counter("queries_shed").inc(7)
    reg.gauge("booting_nodes").set(2)
    # labels inserted b-first must render a-first (stable sorted order)
    reg.histogram("fleet_latency_ms", zone='eu"1"', arch="dlrm\\x").observe(
        4.0)
    golden = (
        "# HELP queries_shed Queries shed by admission control.\n"
        "# TYPE queries_shed counter\n"
        "queries_shed 7\n"
        "# HELP booting_nodes Nodes currently booting.\n"
        "# TYPE booting_nodes gauge\n"
        "booting_nodes 2\n"
        "# HELP fleet_latency_ms End-to-end query latency across the "
        "fleet.\n"
        "# TYPE fleet_latency_ms summary\n"
        'fleet_latency_ms{arch="dlrm\\\\x",quantile="0.5",zone="eu\\"1\\""}'
        " 4\n"
        'fleet_latency_ms{arch="dlrm\\\\x",quantile="0.95",zone="eu\\"1\\"'
        '"} 4\n'
        'fleet_latency_ms{arch="dlrm\\\\x",quantile="0.99",zone="eu\\"1\\"'
        '"} 4\n'
        'fleet_latency_ms_count{arch="dlrm\\\\x",zone="eu\\"1\\""} 1\n'
        'fleet_latency_ms_sum{arch="dlrm\\\\x",zone="eu\\"1\\""} 4\n'
    )
    assert to_prometheus(reg) == golden
    # a family seen under several label sets gets exactly one header pair
    reg.histogram("fleet_latency_ms", zone="us").observe(8.0)
    text = to_prometheus(reg)
    assert text.count("# TYPE fleet_latency_ms summary") == 1
    assert text.count("# HELP fleet_latency_ms") == 1


# ------------------------------------------------- spans + attribution


def test_span_components_telescope_by_construction():
    t = np.array([0.0, 1.0, 2.0])
    st = SpanTable(t)
    st.record_many(np.arange(3), t + 0.01, t + 0.02, t + 0.05)
    st.mark_reroute(np.array([1]), 1.5)              # re-routed at 1.5s
    st.record(1, 1.51, 1.52, 1.55)
    st.add_retry(np.array([2]), 0.004)
    st.finalize(np.array([0.05, 1.55, 2.05]))
    comps = st.components()
    assert set(comps) == set(COMPONENTS)
    total = sum(comps.values())
    np.testing.assert_allclose(total, st.latency(), atol=1e-12)
    assert comps["reroute"][1] == pytest.approx(0.5)
    assert comps["retry"][2] == pytest.approx(0.004)
    span = st.span(1)
    assert span.reroutes == 1 and set(span.stages) == set(STAGES)
    assert span.latency_s == pytest.approx(0.55)


def test_sim_engine_spans_close_against_measured_latency():
    r = _sim_result(n=600)
    tel = r.telemetry
    assert tel is not None
    ok = tel.spans.completed
    assert int(ok.sum()) == r.n_queries - r.dropped
    # the sim fills stamps analytically: components sum *exactly*
    total = sum(tel.spans.components().values())[ok]
    np.testing.assert_allclose(total, tel.spans.latency()[ok], atol=1e-9)
    report = tel.attribution()
    assert report.reconciles(0.05)
    assert report.n_completed == int(ok.sum())
    assert "service" in report.at(95.0).components_s
    assert report.table()                             # renders


def test_telemetry_kill_switch_returns_none():
    r = _sim_result(n=200, telemetry=False)
    assert r.telemetry is None


def test_sim_and_live_engines_agree_on_attribution():
    """Engine consistency: the same trace through the analytic sim and
    real runtime threads must tell the same story — both decompositions
    close, and the dominant component (service) matches the canned
    device curve on both engines."""
    service_s = 2e-3
    n = 120
    times, sizes = _trace(n, 1.2, seed=4)

    sim = drive_fleet(
        times, sizes,
        sim_backends(Fleet([Pool("cpu", NodeSpec(
            cpu=_canned(service_s), n_executors=1, batch_size=16,
            request_overhead_s=0.0), count=2)]).node_views()),
        make_router("round_robin"), window_s=0.25, telemetry=True)

    def apply_fn(batch):
        import time as _t
        _t.sleep(service_s)
        return batch["x"].sum()

    backends = [live_node(apply_fn, lambda size, model_id:
                          {"x": np.ones(size, np.float32)},
                          pool="live", index_in_pool=i,
                          device=_canned(service_s), batch_size=16,
                          max_bucket=64, clock=WallClock())
                for i in range(2)]
    try:
        live = drive_fleet(times, sizes, backends,
                           make_router("round_robin"), window_s=0.25,
                           telemetry=True)
    finally:
        for b in backends:
            b.close()

    rs, rl = sim.telemetry.attribution(), live.telemetry.attribution()
    assert rs.reconciles(0.05) and rl.reconciles(0.05)
    p50s = rs.at(50.0).components_s["service"]
    p50l = rl.at(50.0).components_s["service"]
    assert p50s == pytest.approx(service_s, rel=0.2)
    # live stamps real threads: service = sleep + runtime overhead
    assert service_s * 0.8 <= p50l <= service_s * 3.0
    # both engines' spans cover the completed population
    assert rs.n_completed == n and rl.n_completed == n


def test_sim_kill_shows_reroute_component_calm_shows_none():
    # dense trace + slow service so node 0 has a deep pending queue when
    # the kill lands — those orphans re-route and carry reroute span time
    kw = dict(n=600, horizon=0.3, count=2, window_s=0.05, service_s=4e-2)
    faults = FleetFaults(kills=(NodeKill(0.1, "cpu", 0),))
    chaos = _sim_result(faults=faults, **kw)
    calm = _sim_result(**kw)
    for r in (chaos, calm):
        assert r.telemetry.attribution().reconciles(0.05)
    ck = chaos.telemetry.spans.components()
    ok = chaos.telemetry.spans.completed
    assert chaos.rerouted > 0
    assert float(ck["reroute"][ok].sum()) > 0.0
    assert (chaos.telemetry.spans.reroutes > 0).sum() == chaos.rerouted
    calm_comps = calm.telemetry.spans.components()
    assert float(calm_comps["reroute"].sum()) == 0.0
    assert calm.telemetry.registry.counter("queries_rerouted").value == 0.0


@pytest.mark.slow
def test_remote_retry_stall_lands_in_retry_component():
    """A scripted RPC hang on a real worker process: the client's
    deadline/retry machinery recovers, and the stall is attributed to
    the in-flight queries' retry component (zero on a calm run)."""
    from repro.cluster.remote import RemoteBackendFactory, WorkerSupervisor

    times, sizes = _trace(16, 1.0, seed=2)

    def run(plan):
        clock = WallClock()
        with WorkerSupervisor() as sup:
            factory = RemoteBackendFactory(
                "pybusy:50000", sup, device=_canned(2.5e-2), batch_size=16,
                max_bucket=64, clock=clock, chaos=plan,
                rpc_timeout=0.3, rpc_retries=3)
            spec = NodeSpec(cpu=_canned(2.5e-2), n_executors=1,
                            batch_size=16, request_overhead_s=0.0)
            fleet = Fleet([Pool("remote", spec, count=1)])
            try:
                return drive_fleet(times, sizes, None,
                                   make_router("round_robin"),
                                   window_s=0.25, fleet=fleet,
                                   factory=factory, fleet_faults=plan,
                                   telemetry=True, drain_timeout=60)
            finally:
                factory.close()

    plan = ChaosPlan(hangs=(RpcHang(0.3, "remote", 0, hang_s=0.8),))
    chaos = run(plan)
    calm = run(None)
    ok = chaos.telemetry.spans.completed
    retry = float(chaos.telemetry.spans.components()["retry"][ok].sum())
    assert retry > 0.0
    assert chaos.telemetry.registry.counter("rpc_retry_seconds").value > 0.0
    assert chaos.telemetry.registry.counter("rpc_retries").value >= 1.0
    assert float(calm.telemetry.spans.components()["retry"].sum()) == 0.0
    assert chaos.telemetry.attribution().reconciles(0.05)


def test_live_errors_are_first_class_on_result():
    times, sizes = _trace(60, 0.6, seed=6)

    def apply_fn(batch):
        if len(batch["x"]) > 8:                       # big buckets blow up
            raise RuntimeError("boom")
        return batch["x"].sum()

    backends = [live_node(apply_fn, lambda size, model_id:
                          {"x": np.ones(size, np.float32)},
                          pool="live", index_in_pool=0,
                          device=_canned(1e-3), batch_size=16,
                          max_bucket=64, clock=WallClock())]
    try:
        r = drive_fleet(times, sizes, backends, make_router("round_robin"),
                        window_s=0.2, telemetry=True)
    finally:
        for b in backends:
            b.close()
    assert r.errors > 0
    assert r.errors == sum(r.errors_by_node.values())
    assert set(r.errors_by_node) == {"live[0]"}
    # errored queries also count as dropped (never actually served)
    assert r.error_rate == pytest.approx(
        r.errors / (r.n_queries + r.dropped))
    assert r.telemetry.registry.counter(
        "node_errors", node="live[0]").value == r.errors


# ----------------------------------------------------------- exporters


def test_jsonl_artifact_roundtrip_and_dump(tmp_path):
    r = _sim_result(n=300)
    path = os.path.join(tmp_path, "run.jsonl")
    n_lines = write_jsonl(r, path)
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    assert len(lines) == n_lines
    kinds = {ln["kind"] for ln in lines}
    assert {"run", "window", "attribution", "stage_totals"} <= kinds
    run = next(ln for ln in lines if ln["kind"] == "run")
    assert run["n_queries"] == 300 and run["p95_ms"] is not None
    att = [ln for ln in lines if ln["kind"] == "attribution"]
    assert {a["percentile"] for a in att} == {50.0, 95.0, 99.0}
    for a in att:
        assert abs(a["component_sum_s"] - a["band_latency_s"]) \
            <= 0.05 * a["band_latency_s"]
    # strict JSON: no NaN survived serialization
    assert "NaN" not in open(path).read()
    text = summarize(lines, show_windows=True)
    assert "attribution (ms):" in text and "windows:" in text

    # the same records stream from run_lines without touching disk
    assert sum(1 for _ in run_lines(r)) == n_lines


def test_dump_cli_main(tmp_path, capsys):
    from repro.obs.dump import main as dump_main
    r = _sim_result(n=120)
    path = os.path.join(tmp_path, "run.jsonl")
    write_jsonl(r, path)
    assert dump_main([path]) == 0
    out = capsys.readouterr().out
    assert "run:" in out and "stage totals:" in out


def test_dump_window_filter(tmp_path, capsys):
    from repro.obs.dump import main as dump_main
    r = _sim_result(n=300, horizon=1.0, window_s=0.1)
    path = os.path.join(tmp_path, "run.jsonl")
    write_jsonl(r, path)
    n_windows = len(r.telemetry.timeline.windows)
    assert dump_main([path, "--window", "0.15:0.45"]) == 0  # implies --windows
    out = capsys.readouterr().out
    assert f"windows: {n_windows} (3 selected)" in out
    assert "t=0.20s" in out and "t=0.30s" in out and "t=0.40s" in out
    assert "t=0.50s" not in out and "t=0.10s" not in out
    # open-ended ranges: either side of the colon may be empty
    assert dump_main([path, "--window", "0.75:"]) == 0
    assert "selected)" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        dump_main([path, "--window", "abc"])


def test_dump_node_filter(tmp_path, capsys):
    from repro.obs.dump import main as dump_main
    times, sizes = _trace(60, 0.6, seed=6)

    def apply_fn(batch):
        if len(batch["x"]) > 8:
            raise RuntimeError("boom")
        return batch["x"].sum()

    backends = [live_node(apply_fn, lambda size, model_id:
                          {"x": np.ones(size, np.float32)},
                          pool="live", index_in_pool=i,
                          device=_canned(1e-3), batch_size=16,
                          max_bucket=64, clock=WallClock())
                for i in range(2)]
    try:
        r = drive_fleet(times, sizes, backends, make_router("round_robin"),
                        window_s=0.2, telemetry=True)
    finally:
        for b in backends:
            b.close()
    assert set(r.errors_by_node)            # scenario produced node errors
    path = os.path.join(tmp_path, "run.jsonl")
    write_jsonl(r, path)
    target = sorted(r.errors_by_node)[0]
    other = "live[1]" if target == "live[0]" else "live[0]"
    assert dump_main([path, "--node", target, "--windows"]) == 0
    out = capsys.readouterr().out
    assert f"node errors: {target}=" in out
    assert other not in out.replace(f'node="{target}"', "")
    assert f'node="{target}"' in out        # per-window node metrics shown
