"""Hypothesis property tests (query distributions, layer substrate).

Kept in their own module so the plain unit tests in test_core.py /
test_layers.py still run when hypothesis is absent — only this file skips.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import query_gen as qg
from repro.layers import embedding as E
from repro.layers import moe as M

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------ query gen


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["fixed", "normal", "lognormal", "production"]),
       st.integers(0, 2**31 - 1))
def test_sizes_in_range(kind, seed):
    dist = qg.SizeDist(kind)
    s = dist.sample(np.random.default_rng(seed), 500)
    assert (s >= 1).all() and (s <= dist.max_size).all()


@settings(max_examples=10, deadline=None)
@given(st.floats(10.0, 5000.0))
def test_poisson_arrival_rate(qps):
    rng = np.random.default_rng(0)
    queries = qg.generate_queries(rng, qps, 4000)
    dur = queries[-1].arrival - queries[0].arrival
    assert abs(4000 / dur - qps) / qps < 0.1


# ----------------------------------------------------------- embedding bag


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 50), st.integers(1, 12), st.integers(1, 8),
       st.integers(1, 16))
def test_embedding_bag_matches_loop(vocab, batch, hot, dim):
    table = jax.random.normal(KEY, (vocab, dim))
    idx = jax.random.randint(KEY, (batch, hot), 0, vocab)
    got = E.embedding_bag(table, idx)
    want = np.stack([np.asarray(table)[np.asarray(idx[i])].sum(0)
                     for i in range(batch)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 6), min_size=1, max_size=8))
def test_embedding_bag_ragged_segments(bag_sizes):
    """Ragged bags == per-bag loop sums; empty bags → zero vectors."""
    vocab, dim = 13, 4
    table = jax.random.normal(KEY, (vocab, dim))
    offsets = np.concatenate([[0], np.cumsum(bag_sizes)]).astype(np.int32)
    total = int(offsets[-1])
    idx = np.arange(total) % vocab
    got = E.embedding_bag_ragged(table, jnp.asarray(idx), jnp.asarray(offsets),
                                 num_bags=len(bag_sizes))
    for i, n in enumerate(bag_sizes):
        want = np.asarray(table)[idx[offsets[i]:offsets[i + 1]]].sum(0) \
            if n else np.zeros(dim)
        np.testing.assert_allclose(np.asarray(got[i]), want, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- moe


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 4), st.integers(4, 16))
def test_moe_combine_weights_sum_to_one(top_k, seq):
    p = M.init_moe(KEY, 16, 32, 8, top_k)
    x = jax.random.normal(KEY, (2, seq, 16))
    y, aux = M.apply_moe(p, x, top_k=top_k, capacity_factor=8.0)  # no drops
    assert y.shape == x.shape
    assert float(aux["dropped_frac"]) < 1e-6
    assert np.isfinite(np.asarray(y)).all()
