"""Remote tier: wire-protocol edge cases, worker process lifecycle, real
SIGKILL re-route, supervisor reaping — tiny ``pybusy`` models and canned
device curves keep each worker's useful work small, but every spawn still
pays ~1s of real process boot (tier-1 budget: a handful of spawns)."""
import os
import signal
import socket
import struct
import time

import numpy as np
import pytest

from repro.cluster import (FleetFaults, NodeKill, WallClock, drive_fleet,
                           make_router)
from repro.cluster.fleet import NodeSpec, NodeView, Pool, Fleet
from repro.cluster.live import BucketedDeviceModel
from repro.cluster.remote import (RemoteBackendFactory, WorkerCrashed,
                                  WorkerSupervisor, remote_node)
from repro.serve.remote import (ProtocolError, build_model, recv_frame,
                                send_frame)

pytestmark = pytest.mark.cluster


def _canned_device(service_s: float = 1e-4) -> BucketedDeviceModel:
    return BucketedDeviceModel(np.array([1, 2, 4, 8, 16, 32, 64]),
                               np.full(7, service_s))


def _node(sup, *, index=0, iters=50, service_s=1e-4, clock=None):
    return remote_node(f"pybusy:{iters}", supervisor=sup, pool="remote",
                       index_in_pool=index, device=_canned_device(service_s),
                       batch_size=16, max_bucket=64, clock=clock)


# ------------------------------------------------------------ wire protocol


def test_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        msg = {"op": "submit", "q": [[0, 0.25, 8, -1]]}
        send_frame(a, msg)
        assert recv_frame(b) == msg
    finally:
        a.close()
        b.close()


def test_oversized_frame_rejected_on_send_and_recv():
    a, b = socket.socketpair()
    try:
        with pytest.raises(ProtocolError, match="exceeds"):
            send_frame(a, {"blob": "x" * 1024}, max_frame=64)
        # a peer *announcing* a runaway frame is rejected before the body
        # is read — the declared length alone condemns it
        a.sendall(struct.pack("!I", 2 ** 31))
        with pytest.raises(ProtocolError, match="cap"):
            recv_frame(b, max_frame=1024)
    finally:
        a.close()
        b.close()


def test_partial_frame_raises_not_truncates():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("!I", 100) + b'{"op":')   # die mid-frame
        a.close()
        with pytest.raises(ProtocolError, match="mid-frame"):
            recv_frame(b)
    finally:
        b.close()


def test_clean_eof_returns_none():
    a, b = socket.socketpair()
    a.close()
    try:
        assert recv_frame(b) is None
    finally:
        b.close()


def _fake_server(reply_bytes: bytes, close_after: bool = False):
    """A socketpair 'worker' that reads one request then emits exactly
    ``reply_bytes`` and stalls — or hangs up (``close_after``) — the
    transport-fault bench for the client ``_rpc``."""
    import threading

    client, server = socket.socketpair()

    def _serve():
        try:
            recv_frame(server)                    # consume the request
            if reply_bytes:
                server.sendall(reply_bytes)
        except (OSError, ProtocolError):
            pass
        finally:
            if close_after:
                server.close()

    th = threading.Thread(target=_serve, daemon=True)
    th.start()
    return client, server, th


def test_rpc_garbage_before_header_scraps_socket():
    """Junk bytes where the client expects a frame header read as an
    absurd declared length: the client must scrap the connection (the
    stream is unsyncable), not retry on it."""
    from repro.cluster.remote import _rpc as raw_rpc

    client, server, th = _fake_server(b"\xde\xad\xbe\xef" * 3)
    try:
        with pytest.raises(WorkerCrashed, match="unreachable"):
            raw_rpc(client, {"op": "ping"}, timeout=5.0)
        assert client.fileno() == -1              # scrapped, not reusable
    finally:
        th.join(timeout=5)
        server.close()


def test_rpc_slowloris_partial_frame_trips_deadline():
    """A peer that sends only the header and stalls must trip the per-op
    deadline; the half-read connection is scrapped (a later reply would
    desync against the unread remainder)."""
    from repro.cluster.remote import _rpc as raw_rpc

    client, server, th = _fake_server(struct.pack("!I", 100) + b'{"ok"')
    try:
        with pytest.raises(WorkerCrashed, match="deadline"):
            raw_rpc(client, {"op": "ping"}, timeout=0.3)
        assert client.fileno() == -1
    finally:
        th.join(timeout=5)
        server.close()


def test_rpc_connection_reset_mid_reply():
    """The peer dying mid-reply (announced 100 bytes, delivered 10, then
    closed) is a WorkerCrashed, never a truncated message."""
    from repro.cluster.remote import _rpc as raw_rpc

    client, server, th = _fake_server(struct.pack("!I", 100) + b'{"ok":true',
                                      close_after=True)
    try:
        with pytest.raises(WorkerCrashed, match="unreachable|closed"):
            raw_rpc(client, {"op": "ping"}, timeout=5.0)
        assert client.fileno() == -1
    finally:
        th.join(timeout=5)
        server.close()


def test_build_model_rejects_unknown_spec():
    with pytest.raises(ValueError, match="unknown model"):
        build_model("nosuchmodel:3")
    apply_fn, make_batch = build_model("pybusy:10")
    out = apply_fn(make_batch(4, -1))
    assert out.shape == (1,)


# ------------------------------------------------------- worker lifecycle


def test_worker_roundtrip_and_idempotent_shutdown():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        assert b.spec.boot_s > 0                  # measured, not modeled
        assert sup.healthy(b.handle)
        b.start(0.0)
        b.submit(np.arange(5), np.linspace(0.0, 0.05, 5), np.full(5, 8))
        b.drain(30)
        recs = b.completed_records()
        assert sorted(r.index for r in recs) == list(range(5))
        for r in recs:                            # trace-time coordinates
            assert 0.0 <= r.t_arrival <= r.t_done < 10.0
            assert r.error is None
        # reset gives the same process a fresh run: old records are gone
        b.reset_run()
        assert b.completed_records() == []
        b.close()
        b.close()                                 # double shutdown: no-op
        assert sup.reap() and not sup.handles


def test_live_worker_survives_poisoned_stream_and_reaccepts():
    """An oversized frame poisons the stream: the worker replies with an
    error and hangs up that connection — but the *process* survives and
    re-accepts, so a reconnect reaches the same runtime state."""
    with WorkerSupervisor() as sup:
        b = _node(sup)
        sock = b.handle.sock
        sock.sendall(struct.pack("!I", 64 * 1024 * 1024))
        reply = recv_frame(sock)
        assert reply["ok"] is False and "cap" in reply["error"]
        assert recv_frame(sock) is None           # worker hung up ...
        assert b.handle.alive()                   # ... but did not exit
        b.handle.reconnect()
        assert sup.healthy(b.handle)              # same process, fresh stream
        b.close()


def test_worker_error_reply_keeps_connection_alive():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        reply = b._rpc({"op": "frobnicate"}, check=False)
        assert reply["ok"] is False and "unknown op" in reply["error"]
        assert sup.healthy(b.handle)              # still serving verbs
        b.close()


def test_duplicate_submit_is_idempotent():
    """A resubmitted window (reply lost, client retried) must not feed
    the same queries twice: the worker dedupes on the submit ``seq`` and,
    for seq-less rows, on the query ids themselves."""
    with WorkerSupervisor() as sup:
        b = _node(sup)
        sock = b.handle.sock
        from repro.cluster.remote import _rpc as raw_rpc

        raw_rpc(sock, {"op": "start", "origin": time.monotonic()})
        frame = {"op": "submit", "q": [[0, 0.0, 4, -1], [1, 0.0, 4, -1]],
                 "seq": 1}
        first = raw_rpc(sock, frame)
        assert first["accepted"] == 2
        again = raw_rpc(sock, frame)              # the retried window
        assert again["ok"] and again["accepted"] == 0 and again["dup"]
        # a *new* seq carrying already-accepted qids: qid-level dedup
        qid_dup = raw_rpc(sock, {"op": "submit", "q": [[1, 0.0, 4, -1]],
                                 "seq": 2})
        assert qid_dup["accepted"] == 0
        raw_rpc(sock, {"op": "drain", "timeout": 30})
        recs = raw_rpc(sock, {"op": "poll", "cursor": 0})["records"]
        assert sorted(r[0] for r in recs) == [0, 1]   # each served once
        b.close()


def test_hung_rpc_deadline_retry_reconnect_recovers():
    """The full SUSPECT round-trip: an armed hang drives the ping past
    its deadline (socket scrapped, node suspect), the retry reconnects to
    the re-accepting process, and the verb lands — no query lost, no
    process restarted."""
    with WorkerSupervisor() as sup:
        b = _node(sup)
        pid = b.handle.pid
        b.rpc_timeout = 0.4           # deadline well under the 1.2s hang
        b._rpc({"op": "chaos", "mode": "hang", "seconds": 1.2}, retries=0)
        reply = b._rpc({"op": "ping"}, retries=4)
        assert reply["ok"] and reply["pid"] == pid    # same process
        assert not b.suspect          # cleared on the first success
        b.close()


def test_hung_rpc_exhausted_retries_marks_suspect():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        b.rpc_timeout = 0.3
        b._rpc({"op": "chaos", "mode": "hang", "seconds": 30.0}, retries=0)
        with pytest.raises(WorkerCrashed, match="deadline"):
            b._rpc({"op": "ping"}, retries=0)
        assert b.suspect
        # verify() goes through the retry path's reconnect — but the
        # worker is still sleeping inside the hang, so a short deadline
        # keeps failing; the node stays suspect until the hang drains
        b.handle.proc.kill()
        b._killed = True              # closed via kill: skip graceful path


def test_garbled_reply_scraps_and_recovers():
    """An armed garble poisons the reply framing: the client sees a
    ProtocolError (absurd declared length), scraps the socket, and the
    retry's reconnect reaches the same process."""
    with WorkerSupervisor() as sup:
        b = _node(sup)
        pid = b.handle.pid
        b._rpc({"op": "chaos", "mode": "garble"}, retries=0)
        reply = b._rpc({"op": "ping"}, retries=2)
        assert reply["ok"] and reply["pid"] == pid
        b.close()


def test_dropped_reply_resubmit_not_double_fed():
    """An armed drop loses a submit's reply; the retry resubmits the same
    window over a fresh connection and the seq dedup makes it a no-op —
    every query still served exactly once."""
    with WorkerSupervisor() as sup:
        b = _node(sup)
        b.start(0.0)
        b._rpc({"op": "chaos", "mode": "drop"}, retries=0)
        b.submit(np.arange(4), np.zeros(4), np.full(4, 4))
        b.drain(30)
        recs = b.completed_records()
        assert sorted(r.index for r in recs) == [0, 1, 2, 3]
        b.close()


def test_supervisor_heal_respawns_within_budget():
    """heal() = reap + policy-budgeted respawn: a killed worker comes
    back as generation+1 with the same launch config; a corpse past the
    budget stays dead."""
    from repro.cluster.remote import RestartPolicy

    with WorkerSupervisor(restart=RestartPolicy(max_restarts=2,
                                                backoff_s=0.0)) as sup:
        h = sup.spawn("pybusy:50", n_workers=1, batch_size=16,
                      max_bucket=64)
        os.kill(h.pid, signal.SIGKILL)
        h.proc.wait(timeout=10)
        healed = sup.heal()
        assert len(healed) == 1
        corpse, fresh = healed[0]
        assert corpse.pid == h.pid and fresh is not None
        assert fresh.generation == 1
        assert fresh.config == dict(n_workers=1, batch_size=16,
                                    max_bucket=64)
        assert sup.healthy(fresh)
        # exhaust the lineage budget: a generation-2 corpse is not revived
        fresh.generation = 2
        os.kill(fresh.pid, signal.SIGKILL)
        fresh.proc.wait(timeout=10)
        assert sup.heal() == [(fresh, None)]
        assert not sup.handles


def test_await_port_tolerates_stdout_noise():
    """A worker (or a library it imports) printing to stdout before the
    announce must not starve the rendezvous: a block-buffered pipe ships
    the noise and the announce in one chunk, which a select()-based
    reader would lose into its line buffer."""
    import subprocess
    import sys

    sup = WorkerSupervisor(spawn_timeout=10.0)
    proc = subprocess.Popen(
        [sys.executable, "-c",
         "print('import-time noise'); print('REMOTE_WORKER_PORT=7')"],
        stdout=subprocess.PIPE)
    try:
        assert sup._await_port(proc) == 7
    finally:
        proc.wait(timeout=10)


def test_supervisor_reaps_sigkilled_zombie():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        pid = b.handle.pid
        os.kill(pid, signal.SIGKILL)
        deadline = time.monotonic() + 10
        while b.handle.proc.poll() is None and time.monotonic() < deadline:
            time.sleep(0.02)
        dead = sup.reap()
        assert [h.pid for h in dead] == [pid]
        assert dead[0].proc.returncode == -signal.SIGKILL   # no zombie left
        assert not sup.handles
        assert not sup.healthy(dead[0])


# --------------------------------------------------- kill/re-route, fleet


def test_worker_crash_mid_query_orphans_rerouted_via_lifecycle():
    """A mid-run SIGKILL (the FleetFaults path: cancel_pending kills the
    real process) surrenders the victim's unfinished queries and the
    driver re-routes them to the survivor — none lost."""
    clock = WallClock()
    with WorkerSupervisor() as sup:
        # ~50ms/query of GIL-held python work against 20ms arrivals → the
        # victim is over capacity and has a queue when the kill lands
        backends = [_node(sup, index=i, iters=60000, service_s=5e-2,
                          clock=clock) for i in range(2)]
        times = np.linspace(0.0, 0.4, 40)
        sizes = np.full(40, 8, np.int64)
        faults = FleetFaults(kills=(NodeKill(0.2, "remote", 0),))
        try:
            r = drive_fleet(times, sizes, backends,
                            make_router("round_robin"), window_s=0.1,
                            fleet_faults=faults, drain_timeout=60)
            assert r.rerouted > 0
            assert r.dropped == 0 and r.n_queries == 40
            assert backends[0].handle.proc.returncode == -signal.SIGKILL
            with pytest.raises(RuntimeError, match="dead"):
                backends[0].submit(np.array([99]), np.array([0.9]),
                                   np.array([4]))
            with pytest.raises(WorkerCrashed):
                backends[0]._rpc({"op": "ping"})
            # the dead node's polled history + the survivor's records
            # partition the trace
            done = {rec.index for b in backends
                    for rec in b.completed_records()}
            assert done == set(range(40))
            assert [h.pid for h in sup.reap()] == [backends[0].handle.pid]
        finally:
            for b in backends:
                b.close()


def test_async_factory_orders_return_instantly():
    """Boot-ahead: an async factory order costs the caller microseconds —
    the ~1s process spawn happens in a background thread and the proxy
    promotes once the worker is actually serving."""
    with WorkerSupervisor() as sup:
        factory = RemoteBackendFactory("pybusy:50", sup,
                                       device=_canned_device(),
                                       batch_size=16, max_bucket=64,
                                       async_boot=True)
        spec = NodeSpec(cpu=_canned_device(), n_executors=1, batch_size=16,
                        request_overhead_s=0.0)
        fleet = Fleet([Pool("remote", spec, count=1)])
        view = fleet.node_views()[0]
        t0 = time.monotonic()
        b = factory(view, 0.0)
        assert time.monotonic() - t0 < 0.5        # no spawn stall inline
        try:
            assert b.wait_ready(60)               # resolves to a live proc
            assert b.handle.alive()
            assert factory.boot_history[0][0] == ("remote", 0)
            b.start(0.0)
            b.submit(np.array([0]), np.array([0.0]), np.array([4]))
            b.drain(30)
            assert len(b.completed_records()) == 1
        finally:
            b.close()
            factory.close()


def test_remote_crash_storm_self_heals_end_to_end():
    """The tentpole round-trip on real processes: a crash storm SIGKILLs
    a worker mid-trace, its orphans re-route to the survivor, and the
    SelfHealPolicy re-materializes the dead node through BOOTING — no
    query lost, the driver never stalls a full window on the respawn."""
    from repro.cluster import ChaosPlan, NodeState, SelfHealPolicy
    from repro.cluster.chaos import crash_storm

    clock = WallClock()
    with WorkerSupervisor() as sup:
        # ~200ms of GIL-held work per query against ~100ms per-node
        # arrivals: the victim is over capacity and has a queue when the
        # kill lands, so real orphans re-route
        factory = RemoteBackendFactory("pybusy:400000", sup,
                                       device=_canned_device(2e-1),
                                       batch_size=16, max_bucket=64,
                                       clock=clock, async_boot=True)
        spec = NodeSpec(cpu=_canned_device(2e-1), n_executors=1,
                        batch_size=16, request_overhead_s=0.0)
        fleet = Fleet([Pool("remote", spec, count=2)])
        plan = ChaosPlan(kills=crash_storm(0.5, "remote", [0]))
        times = np.linspace(0.0, 1.5, 30)
        sizes = np.full(30, 4, np.int64)
        try:
            r = drive_fleet(times, sizes, None, make_router("round_robin"),
                            window_s=0.25, fleet=fleet, factory=factory,
                            fleet_faults=plan,
                            self_heal=SelfHealPolicy(max_restarts=1,
                                                     backoff_s=0.0),
                            drain_timeout=60)
        finally:
            factory.close()
        assert r.dropped == 0 and r.rerouted > 0
        seq = [e.state for e in r.lifecycle
               if (e.pool, e.index_in_pool) == ("remote", 0)]
        i = seq.index(NodeState.DEAD)
        assert NodeState.BOOTING in seq[i:]       # the heal re-ordered it
        # the respawn must not have stalled the driver a whole window
        assert max(r.driver_stall_s()) < 0.25


def test_remote_backend_factory_boots_real_process():
    """The fleet-mode factory contract: factory(view, t0) spawns a genuine
    worker process and records its measured boot time."""
    with WorkerSupervisor() as sup:
        factory = RemoteBackendFactory("pybusy:50", sup,
                                       device=_canned_device(),
                                       batch_size=16, max_bucket=64)
        spec = NodeSpec(cpu=_canned_device(), n_executors=1, batch_size=16,
                        request_overhead_s=0.0)
        fleet = Fleet([Pool("remote", spec, count=1)])
        view = fleet.node_views()[0]
        b = factory(view, 0.0)
        try:
            assert b.handle.alive()
            assert factory.boot_history[0][0] == ("remote", 0)
            assert factory.boot_history[0][1] > 0
            b.start(0.0)
            b.submit(np.array([0]), np.array([0.0]), np.array([4]))
            b.drain(30)
            assert len(b.completed_records()) == 1
        finally:
            b.close()
