"""Remote tier: wire-protocol edge cases, worker process lifecycle, real
SIGKILL re-route, supervisor reaping — tiny ``pybusy`` models and canned
device curves keep each worker's useful work small, but every spawn still
pays ~1s of real process boot (tier-1 budget: a handful of spawns)."""
import os
import signal
import socket
import struct
import time

import numpy as np
import pytest

from repro.cluster import (FleetFaults, NodeKill, WallClock, drive_fleet,
                           make_router)
from repro.cluster.fleet import NodeSpec, NodeView, Pool, Fleet
from repro.cluster.live import BucketedDeviceModel
from repro.cluster.remote import (RemoteBackendFactory, WorkerCrashed,
                                  WorkerSupervisor, remote_node)
from repro.serve.remote import (ProtocolError, build_model, recv_frame,
                                send_frame)

pytestmark = pytest.mark.cluster


def _canned_device(service_s: float = 1e-4) -> BucketedDeviceModel:
    return BucketedDeviceModel(np.array([1, 2, 4, 8, 16, 32, 64]),
                               np.full(7, service_s))


def _node(sup, *, index=0, iters=50, service_s=1e-4, clock=None):
    return remote_node(f"pybusy:{iters}", supervisor=sup, pool="remote",
                       index_in_pool=index, device=_canned_device(service_s),
                       batch_size=16, max_bucket=64, clock=clock)


# ------------------------------------------------------------ wire protocol


def test_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        msg = {"op": "submit", "q": [[0, 0.25, 8, -1]]}
        send_frame(a, msg)
        assert recv_frame(b) == msg
    finally:
        a.close()
        b.close()


def test_oversized_frame_rejected_on_send_and_recv():
    a, b = socket.socketpair()
    try:
        with pytest.raises(ProtocolError, match="exceeds"):
            send_frame(a, {"blob": "x" * 1024}, max_frame=64)
        # a peer *announcing* a runaway frame is rejected before the body
        # is read — the declared length alone condemns it
        a.sendall(struct.pack("!I", 2 ** 31))
        with pytest.raises(ProtocolError, match="cap"):
            recv_frame(b, max_frame=1024)
    finally:
        a.close()
        b.close()


def test_partial_frame_raises_not_truncates():
    a, b = socket.socketpair()
    try:
        a.sendall(struct.pack("!I", 100) + b'{"op":')   # die mid-frame
        a.close()
        with pytest.raises(ProtocolError, match="mid-frame"):
            recv_frame(b)
    finally:
        b.close()


def test_clean_eof_returns_none():
    a, b = socket.socketpair()
    a.close()
    try:
        assert recv_frame(b) is None
    finally:
        b.close()


def test_build_model_rejects_unknown_spec():
    with pytest.raises(ValueError, match="unknown model"):
        build_model("nosuchmodel:3")
    apply_fn, make_batch = build_model("pybusy:10")
    out = apply_fn(make_batch(4, -1))
    assert out.shape == (1,)


# ------------------------------------------------------- worker lifecycle


def test_worker_roundtrip_and_idempotent_shutdown():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        assert b.spec.boot_s > 0                  # measured, not modeled
        assert sup.healthy(b.handle)
        b.start(0.0)
        b.submit(np.arange(5), np.linspace(0.0, 0.05, 5), np.full(5, 8))
        b.drain(30)
        recs = b.completed_records()
        assert sorted(r.index for r in recs) == list(range(5))
        for r in recs:                            # trace-time coordinates
            assert 0.0 <= r.t_arrival <= r.t_done < 10.0
            assert r.error is None
        # reset gives the same process a fresh run: old records are gone
        b.reset_run()
        assert b.completed_records() == []
        b.close()
        b.close()                                 # double shutdown: no-op
        assert sup.reap() and not sup.handles


def test_live_worker_rejects_oversized_frame_cleanly():
    """An oversized frame poisons the stream: the worker replies with an
    error, closes the connection, and exits — it does not crash in a way
    the supervisor can't observe, and it does not hang."""
    with WorkerSupervisor() as sup:
        b = _node(sup)
        sock = b.handle.sock
        sock.sendall(struct.pack("!I", 64 * 1024 * 1024))
        reply = recv_frame(sock)
        assert reply["ok"] is False and "cap" in reply["error"]
        assert recv_frame(sock) is None           # worker hung up
        b.handle.proc.wait(timeout=10)            # ... and exited
        assert not b.handle.alive()
        sup.reap()


def test_worker_error_reply_keeps_connection_alive():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        reply = b._rpc({"op": "frobnicate"}, check=False)
        assert reply["ok"] is False and "unknown op" in reply["error"]
        assert sup.healthy(b.handle)              # still serving verbs
        b.close()


def test_await_port_tolerates_stdout_noise():
    """A worker (or a library it imports) printing to stdout before the
    announce must not starve the rendezvous: a block-buffered pipe ships
    the noise and the announce in one chunk, which a select()-based
    reader would lose into its line buffer."""
    import subprocess
    import sys

    sup = WorkerSupervisor(spawn_timeout=10.0)
    proc = subprocess.Popen(
        [sys.executable, "-c",
         "print('import-time noise'); print('REMOTE_WORKER_PORT=7')"],
        stdout=subprocess.PIPE)
    try:
        assert sup._await_port(proc) == 7
    finally:
        proc.wait(timeout=10)


def test_supervisor_reaps_sigkilled_zombie():
    with WorkerSupervisor() as sup:
        b = _node(sup)
        pid = b.handle.pid
        os.kill(pid, signal.SIGKILL)
        deadline = time.monotonic() + 10
        while b.handle.proc.poll() is None and time.monotonic() < deadline:
            time.sleep(0.02)
        dead = sup.reap()
        assert [h.pid for h in dead] == [pid]
        assert dead[0].proc.returncode == -signal.SIGKILL   # no zombie left
        assert not sup.handles
        assert not sup.healthy(dead[0])


# --------------------------------------------------- kill/re-route, fleet


def test_worker_crash_mid_query_orphans_rerouted_via_lifecycle():
    """A mid-run SIGKILL (the FleetFaults path: cancel_pending kills the
    real process) surrenders the victim's unfinished queries and the
    driver re-routes them to the survivor — none lost."""
    clock = WallClock()
    with WorkerSupervisor() as sup:
        # ~50ms/query of GIL-held python work against 20ms arrivals → the
        # victim is over capacity and has a queue when the kill lands
        backends = [_node(sup, index=i, iters=60000, service_s=5e-2,
                          clock=clock) for i in range(2)]
        times = np.linspace(0.0, 0.4, 40)
        sizes = np.full(40, 8, np.int64)
        faults = FleetFaults(kills=(NodeKill(0.2, "remote", 0),))
        try:
            r = drive_fleet(times, sizes, backends,
                            make_router("round_robin"), window_s=0.1,
                            fleet_faults=faults, drain_timeout=60)
            assert r.rerouted > 0
            assert r.dropped == 0 and r.n_queries == 40
            assert backends[0].handle.proc.returncode == -signal.SIGKILL
            with pytest.raises(RuntimeError, match="dead"):
                backends[0].submit(np.array([99]), np.array([0.9]),
                                   np.array([4]))
            with pytest.raises(WorkerCrashed):
                backends[0]._rpc({"op": "ping"})
            # the dead node's polled history + the survivor's records
            # partition the trace
            done = {rec.index for b in backends
                    for rec in b.completed_records()}
            assert done == set(range(40))
            assert [h.pid for h in sup.reap()] == [backends[0].handle.pid]
        finally:
            for b in backends:
                b.close()


def test_remote_backend_factory_boots_real_process():
    """The fleet-mode factory contract: factory(view, t0) spawns a genuine
    worker process and records its measured boot time."""
    with WorkerSupervisor() as sup:
        factory = RemoteBackendFactory("pybusy:50", sup,
                                       device=_canned_device(),
                                       batch_size=16, max_bucket=64)
        spec = NodeSpec(cpu=_canned_device(), n_executors=1, batch_size=16,
                        request_overhead_s=0.0)
        fleet = Fleet([Pool("remote", spec, count=1)])
        view = fleet.node_views()[0]
        b = factory(view, 0.0)
        try:
            assert b.handle.alive()
            assert factory.boot_history[0][0] == ("remote", 0)
            assert factory.boot_history[0][1] > 0
            b.start(0.0)
            b.submit(np.array([0]), np.array([0.0]), np.array([4]))
            b.drain(30)
            assert len(b.completed_records()) == 1
        finally:
            b.close()
