"""Live serving runtime: split/execute/complete, bucketing, online control."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.batching import bucket_for, pad_batch, slice_result
from repro.serve.runtime import (OffloadController, OnlineController,
                                 ServingRuntime)


def test_bucketing():
    assert bucket_for(1) == 1
    assert bucket_for(2) == 2
    assert bucket_for(3) == 4
    assert bucket_for(64) == 64
    assert bucket_for(65) == 128
    assert bucket_for(1024) == 1024
    assert bucket_for(1025, max_bucket=1024) == 1024    # clamped
    assert bucket_for(5000, max_bucket=1024) == 1024
    assert bucket_for(5, max_bucket=4) == 4


def test_pad_and_slice_roundtrip():
    b = {"x": jnp.arange(6.0).reshape(3, 2)}
    p = pad_batch(b, 8)
    assert p["x"].shape == (8, 2)
    out = slice_result(p, 3)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(b["x"]))
    # exact fit: no copy needed, shapes preserved
    q = pad_batch(b, 3)
    assert q["x"].shape == (3, 2)
    # multi-leaf round-trip
    b2 = {"x": jnp.ones((5, 2)), "y": jnp.zeros((5,))}
    p2 = pad_batch(b2, 8)
    assert p2["x"].shape == (8, 2) and p2["y"].shape == (8,)
    out2 = slice_result(p2, 5)
    assert out2["x"].shape == (5, 2) and out2["y"].shape == (5,)


def test_pad_batch_rejects_oversize():
    """A request larger than its bucket means the caller forgot to split —
    pad_batch must refuse instead of silently dropping rows (it used to
    crash with a negative broadcast)."""
    b = {"x": jnp.ones((9, 2))}
    with pytest.raises(ValueError, match="split oversize"):
        pad_batch(b, 8)


def test_submit_rejects_zero_size():
    """size=0 would enqueue zero requests but leave a permanent
    _outstanding entry, deadlocking drain()."""
    rt = _runtime()
    try:
        with pytest.raises(ValueError, match="size"):
            rt.submit(0, {"x": jnp.ones((0, 4))}, 0)
    finally:
        rt.shutdown()


def test_runtime_splits_oversize_when_knob_exceeds_bucket():
    """The online controller can climb batch_size past max_bucket; submit
    must cap request size at max_bucket so no request outruns its bucket."""
    rt = _runtime(batch_size=64)
    rt.max_bucket = 16
    try:
        rt.submit(0, {"x": jnp.ones((50, 4))}, 50)      # → ⌈50/16⌉ requests
        rt.drain(timeout=60)
        recs = rt.completed()
        assert len(recs) == 1 and recs[0].latency_ms > 0
    finally:
        rt.shutdown()


def _runtime(batch_size=32, n_workers=2):
    w = jnp.ones((4, 1)) * 0.5

    @jax.jit
    def apply_fn(batch):
        return batch["x"] @ w

    return ServingRuntime(apply_fn, n_workers=n_workers, batch_size=batch_size)


def test_runtime_completes_queries():
    rt = _runtime()
    try:
        rng = np.random.default_rng(0)
        for qid in range(20):
            size = int(rng.integers(1, 200))
            rt.submit(qid, {"x": jnp.ones((size, 4))}, size)
        rt.drain(timeout=60)
        recs = rt.completed()
        assert len(recs) == 20
        assert all(r.latency_ms > 0 for r in recs)
    finally:
        rt.shutdown()


def test_runtime_splits_by_batch_size():
    rt = _runtime(batch_size=16)
    try:
        rt.submit(0, {"x": jnp.ones((100, 4))}, 100)   # → 7 requests
        rt.drain(timeout=60)
        assert len(rt.completed()) == 1
    finally:
        rt.shutdown()


def test_pad_batch_numpy_stays_numpy():
    """numpy leaves are padded host-side (no per-shape XLA compile churn);
    device leaves keep the jnp path."""
    p = pad_batch({"x": np.ones((3, 2), np.float32)}, 8)
    assert isinstance(p["x"], np.ndarray) and p["x"].shape == (8, 2)
    q = pad_batch({"x": jnp.ones((3, 2))}, 8)
    assert not isinstance(q["x"], np.ndarray) and q["x"].shape == (8, 2)


def test_worker_error_surfaces_and_drain_completes():
    """An apply_fn exception must not kill the worker or strand the
    query's _outstanding entry (which used to deadlock drain()); the error
    is carried on the QueryRecord."""
    calls = []

    def apply_fn(batch):
        calls.append(batch["x"].shape[0])
        if len(calls) == 1:
            raise RuntimeError("boom")
        return batch["x"].sum()

    rt = ServingRuntime(apply_fn, n_workers=1, batch_size=32)
    try:
        rt.submit(0, {"x": np.ones((8, 2), np.float32)}, 8)
        rt.drain(timeout=30)                     # must not deadlock
        rt.submit(1, {"x": np.ones((8, 2), np.float32)}, 8)
        rt.drain(timeout=30)                     # worker still alive
        bad, good = rt.record(0), rt.record(1)
        assert bad.t_done > 0 and "boom" in bad.error
        assert good.t_done > 0 and good.error is None
    finally:
        rt.shutdown()


def test_online_controller_steps_down_on_sla_violation():
    rt = _runtime(batch_size=64)
    ctl = OnlineController(rt, sla_ms=0.0001, window=5)   # impossible SLA
    try:
        for qid in range(10):
            rt.submit(qid, {"x": jnp.ones((64, 4))}, 64)
        rt.drain(timeout=60)
        ctl.step()
        assert rt.batch_size < 64                          # stepped down
    finally:
        rt.shutdown()


def test_online_controller_steps_up_when_headroom():
    rt = _runtime(batch_size=16)
    ctl = OnlineController(rt, sla_ms=1e6, window=5)       # infinite headroom
    try:
        for qid in range(10):
            rt.submit(qid, {"x": jnp.ones((16, 4))}, 16)
        rt.drain(timeout=60)
        ctl.step()
        assert rt.batch_size > 16
    finally:
        rt.shutdown()


def _fed_controller(batch_size, sla_ms, ladder=None):
    """A controller whose runtime has a full window of completed queries."""
    rt = _runtime(batch_size=batch_size)
    kwargs = {} if ladder is None else {"ladder": ladder}
    ctl = OnlineController(rt, sla_ms=sla_ms, window=5, **kwargs)
    for qid in range(6):
        rt.submit(qid, {"x": jnp.ones((8, 4))}, 8)
    rt.drain(timeout=60)
    return rt, ctl


def test_online_controller_snaps_off_ladder_knob():
    """A runtime constructed with a batch size not on the ladder used to
    raise ValueError in step(); it must snap to the nearest rung and keep
    climbing from there."""
    rt, ctl = _fed_controller(batch_size=48, sla_ms=1e6)   # 48 ∉ ladder
    try:
        ctl.step()                                          # must not raise
        assert rt.batch_size in ctl.ladder
        assert rt.batch_size == 64           # snapped to 32|64, headroom → up
    finally:
        rt.shutdown()


def test_online_controller_clamps_at_ladder_ends():
    rt, ctl = _fed_controller(batch_size=1, sla_ms=1e-6)   # breach at floor
    try:
        ctl.step()
        assert rt.batch_size == 1                           # clamped
    finally:
        rt.shutdown()
    rt, ctl = _fed_controller(batch_size=16, sla_ms=1e6, ladder=(4, 8, 16))
    try:
        ctl.step()
        assert rt.batch_size == 16             # top of the ladder: clamped
    finally:
        rt.shutdown()


def test_online_controller_holds_inside_hysteresis_band():
    """p95 between 0.7×SLA and SLA: neither step direction fires."""
    rt, ctl = _fed_controller(batch_size=16, sla_ms=1.0)
    try:
        done = rt.completed()
        p95 = float(np.percentile([r.latency_ms for r in done], 95))
        ctl.sla_ms = p95 / 0.85                # 0.7×SLA < p95 < SLA
        ctl.step()
        assert rt.batch_size == 16
        assert ctl.history and ctl.history[-1][0] == 16
    finally:
        rt.shutdown()


# --------------------------------------------- offload-threshold controller


def test_offload_controller_breach_steps_toward_unloaded_path():
    ctl = OffloadController(sla_ms=100.0, threshold=300)
    # CPU queueing dominates -> offload more (threshold down one rung)
    assert ctl.step(250.0, cpu_queue_p99_ms=80.0, acc_queue_p99_ms=5.0) == 200
    # accelerator queueing dominates -> keep work on CPU (up one rung)
    assert ctl.step(250.0, cpu_queue_p99_ms=5.0, acc_queue_p99_ms=80.0) == 300
    assert [h[0] for h in ctl.history] == [200, 300]


def test_offload_controller_headroom_drifts_to_prefer():
    ctl = OffloadController(sla_ms=100.0, threshold=300)
    ctl.threshold = 50                     # emergency moves left it low
    assert ctl.step(10.0, 0.0, 0.0) == 100   # one rung back toward 300
    assert ctl.step(10.0, 0.0, 0.0) == 150
    # from above, drift comes DOWN toward prefer too
    ctl.threshold = 700
    assert ctl.step(10.0, 0.0, 0.0) == 450


def test_offload_controller_holds_on_nan_and_mid_band():
    ctl = OffloadController(sla_ms=100.0, threshold=300)
    assert ctl.step(float("nan"), 1.0, 1.0) == 300      # empty window
    assert ctl.step(80.0, 50.0, 1.0) == 300             # inside the band
    # NaN queue components during a breach default to zero, not a crash
    assert ctl.step(250.0, float("nan"), float("nan")) == 200


def test_offload_controller_snaps_and_clamps():
    assert OffloadController(sla_ms=1.0, threshold=None).threshold == 1001
    assert OffloadController(sla_ms=1.0, threshold=333).threshold == 300
    ctl = OffloadController(sla_ms=100.0, threshold=1)
    assert ctl.step(500.0, 10.0, 0.0) == 1              # clamped at floor
    ctl2 = OffloadController(sla_ms=100.0, threshold=1001)
    assert ctl2.step(500.0, 0.0, 10.0) == 1001          # clamped at top
