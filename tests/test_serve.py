"""Live serving runtime: split/execute/complete, bucketing, online control."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.batching import bucket_for, pad_batch, slice_result
from repro.serve.runtime import OnlineController, ServingRuntime


def test_bucketing():
    assert bucket_for(1) == 1
    assert bucket_for(3) == 4
    assert bucket_for(64) == 64
    assert bucket_for(65) == 128
    assert bucket_for(5000, max_bucket=1024) == 1024


def test_pad_and_slice_roundtrip():
    b = {"x": jnp.arange(6.0).reshape(3, 2)}
    p = pad_batch(b, 8)
    assert p["x"].shape == (8, 2)
    out = slice_result(p, 3)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(b["x"]))


def _runtime(batch_size=32, n_workers=2):
    w = jnp.ones((4, 1)) * 0.5

    @jax.jit
    def apply_fn(batch):
        return batch["x"] @ w

    return ServingRuntime(apply_fn, n_workers=n_workers, batch_size=batch_size)


def test_runtime_completes_queries():
    rt = _runtime()
    try:
        rng = np.random.default_rng(0)
        for qid in range(20):
            size = int(rng.integers(1, 200))
            rt.submit(qid, {"x": jnp.ones((size, 4))}, size)
        rt.drain(timeout=60)
        recs = rt.completed()
        assert len(recs) == 20
        assert all(r.latency_ms > 0 for r in recs)
    finally:
        rt.shutdown()


def test_runtime_splits_by_batch_size():
    rt = _runtime(batch_size=16)
    try:
        rt.submit(0, {"x": jnp.ones((100, 4))}, 100)   # → 7 requests
        rt.drain(timeout=60)
        assert len(rt.completed()) == 1
    finally:
        rt.shutdown()


def test_online_controller_steps_down_on_sla_violation():
    rt = _runtime(batch_size=64)
    ctl = OnlineController(rt, sla_ms=0.0001, window=5)   # impossible SLA
    try:
        for qid in range(10):
            rt.submit(qid, {"x": jnp.ones((64, 4))}, 64)
        rt.drain(timeout=60)
        ctl.step()
        assert rt.batch_size < 64                          # stepped down
    finally:
        rt.shutdown()


def test_online_controller_steps_up_when_headroom():
    rt = _runtime(batch_size=16)
    ctl = OnlineController(rt, sla_ms=1e6, window=5)       # infinite headroom
    try:
        for qid in range(10):
            rt.submit(qid, {"x": jnp.ones((16, 4))}, 16)
        rt.drain(timeout=60)
        ctl.step()
        assert rt.batch_size > 16
    finally:
        rt.shutdown()
