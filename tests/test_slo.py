"""SLO engine: burn-rate alerting, breach diagnosis, incident stitching,
and the diagnosis-driven control loop.

Unit tests feed hand-built window snapshots (a registry + timeline pair,
no engine run) so every verdict and alert transition is pinned against
known component mixes; integration tests drive small traces through the
sim and live engines and assert the *same* verdicts come out of real
window sketches.
"""
import json
import os

import numpy as np
import pytest

from repro.cluster import (Autoscaler, DiagnosisPolicy, Fleet, NodeSpec,
                           Pool, TelemetrySignal, drive_fleet, live_node,
                           make_router, sim_backends, simulate_fleet)
from repro.cluster.live import BucketedDeviceModel, WallClock
from repro.core.latency_model import TableDeviceModel
from repro.obs import (BreachDiagnoser, BurnRateRule, ControlAction,
                       FleetTimeline, IncidentLog, MetricsRegistry,
                       SloEngine, SloObjective, Verdict, write_jsonl)
from repro.obs.diagnose import Diagnosis
from repro.obs.report import render as report_render
from repro.obs.slo import AlertEvent

pytestmark = pytest.mark.cluster

CPU = TableDeviceModel(np.array([1., 4, 16, 64, 256, 1024]),
                       np.array([.0008, .001, .0018, .0045, .015, .058]))


def _canned(service_s: float) -> BucketedDeviceModel:
    return BucketedDeviceModel(np.array([1, 2, 4, 8, 16, 32, 64]),
                               np.full(7, service_s))


class _Feed:
    """Synthetic window feeder: builds the same frozen-snapshot stream
    the driver hands the engine, from explicit latency samples and
    per-query component averages."""

    def __init__(self, width_s: float = 0.5):
        self.reg = MetricsRegistry()
        self.tl = FleetTimeline()
        self.width = width_s
        self.t = 0.0

    def window(self, lat_ms, comps=None, *, metric="fleet_latency_ms",
               hit_rate=None, booting=None, shed=0, err=0):
        lat = np.asarray(lat_ms, float)
        if "{" in metric:
            name, label = metric.split("{")
            key, val = label.rstrip("}").split("=")
            self.reg.histogram(name, **{key: val.strip('"')}
                               ).observe_many(lat)
        else:
            self.reg.histogram(metric).observe_many(lat)
        for c, per_q in (comps or {}).items():
            self.reg.histogram(f"span_{c}_ms").observe_many(
                np.full(len(lat), per_q))
        if hit_rate is not None:
            self.reg.gauge("cache_hit_rate").set(hit_rate)
        if booting is not None:
            self.reg.gauge("booting_nodes").set(booting)
        if shed:
            self.reg.counter("queries_shed").inc(shed)
        if err:
            self.reg.counter("node_errors", node="n0").inc(err)
        snap = self.tl.snapshot(self.reg, self.t, self.width)
        self.t += self.width
        return snap


def _engine(bound_ms=100.0, rules=(BurnRateRule(4, 2, 2.0),), **kw):
    return SloEngine(SloObjective("p95", latency_ms=bound_ms), rules=rules,
                     **kw)


# ------------------------------------------------------------ objectives


def test_objective_budget_and_metric():
    o = SloObjective("a", latency_ms=50.0)
    assert o.budget == pytest.approx(0.05)
    assert o.metric == "fleet_latency_ms"
    m = SloObjective("b", latency_ms=50.0, percentile=99.0, error_rate=0.01,
                     model_id=7)
    assert m.budget == pytest.approx(0.02)
    assert m.metric == 'model_latency_ms{model="7"}'
    with pytest.raises(ValueError):
        SloEngine(())


# --------------------------------------------------- burn-rate alerting


def test_burn_rate_fires_on_sustained_burn_and_clears():
    eng, feed = _engine(), _Feed()
    calm = np.full(40, 5.0)
    # 30% of the window over the bound: burn 0.3/0.05 = 6
    hot = np.where(np.arange(40) < 12, 400.0, 5.0)
    for _ in range(4):
        eng.on_window(feed.window(calm))
    assert not eng.alerts
    eng.on_window(feed.window(hot))    # long avg 6/4 = 1.5 < 2: no page
    assert not eng.alerts
    eng.on_window(feed.window(hot))    # long avg 3, short avg 6 — fire
    assert [a.kind for a in eng.alerts] == ["fire"]
    assert eng.alerts[0].rule == 0 and eng.alerts[0].burn_short >= 2.0
    eng.on_window(feed.window(calm))   # short [6, 0] avg 3: still matching
    assert len(eng.alerts) == 1
    eng.on_window(feed.window(calm))   # short [0, 0] — clear
    assert [a.kind for a in eng.alerts] == ["fire", "clear"]
    assert len(eng.incidents) == 1
    inc = eng.incidents[0]
    assert inc.t_end is not None and inc.duration_s == pytest.approx(1.0)
    assert eng.violation_minutes() == pytest.approx(2 * 0.5 / 60.0)


def test_calm_run_is_silent_and_builds_baseline():
    eng, feed = _engine(), _Feed()
    for _ in range(20):
        eng.on_window(feed.window(np.full(30, 4.0),
                                  comps={"service": 4.0}, hit_rate=0.5))
    assert not eng.alerts and not eng.diagnoses and not eng.incidents
    assert eng.violation_minutes() == 0.0
    assert eng.diagnoser.calm_windows == 20
    assert eng.diagnoser.baseline["service"] == pytest.approx(4.0)
    assert eng.diagnoser.baseline_hit_rate == pytest.approx(0.5)


def test_first_window_never_pages():
    eng, feed = _engine(rules=(BurnRateRule(1, 1, 1.0),)), _Feed()
    # even an instant-fire rule needs short_windows of history
    eng.on_window(feed.window(np.full(10, 500.0)))
    assert [a.kind for a in eng.alerts] == ["fire"]
    eng2, feed2 = _engine(rules=(BurnRateRule(4, 2, 1.0),)), _Feed()
    eng2.on_window(feed2.window(np.full(10, 500.0)))
    assert not eng2.alerts


def test_shed_and_errors_count_against_fleet_budget():
    eng, feed = _engine(bound_ms=100.0), _Feed()
    # all served latencies healthy, but half the offered load was shed
    eng.on_window(feed.window(np.full(10, 5.0), shed=10, err=2))
    (_, _, _, burn) = eng.track["p95"][0]
    assert burn == pytest.approx((12 / 22) / 0.05)
    # second window: counters are cumulative, deltas must be per-window
    eng.on_window(feed.window(np.full(10, 5.0)))
    (_, _, _, burn2) = eng.track["p95"][1]
    assert burn2 == 0.0


def test_model_scoped_objective_reads_model_stream():
    eng = SloEngine((SloObjective("fleet", latency_ms=100.0),
                     SloObjective("tenant7", latency_ms=100.0, model_id=7)),
                    rules=(BurnRateRule(1, 1, 1.0),))
    feed = _Feed()
    feed.reg.histogram("model_latency_ms", model="7").observe_many(
        np.full(20, 400.0))
    eng.on_window(feed.window(np.full(40, 5.0)))
    fired = {a.objective for a in eng.alerts if a.kind == "fire"}
    assert fired == {"tenant7"}
    assert eng.violation_minutes("tenant7") > 0
    assert eng.violation_minutes("fleet") == 0.0
    with pytest.raises(KeyError):
        eng.violation_minutes("nope")


# --------------------------------------------------------- diagnosis


CALM = {"service": 2.0, "queueing": 0.5}


@pytest.mark.parametrize("comps,hit_rate,expect", [
    ({"service": 2.0, "queueing": 60.0}, None,
     Verdict.QUEUEING_SATURATION),
    ({"service": 2.0, "queueing": 10.0, "reroute": 40.0}, None,
     Verdict.FAULT_RECOVERY),
    ({"service": 2.0, "retry": 30.0, "queueing": 8.0}, None,
     Verdict.FAULT_RECOVERY),
    ({"service": 2.0, "boot_wait": 50.0, "queueing": 10.0}, None,
     Verdict.COLD_CAPACITY),
    ({"service": 2.0, "queueing": 30.0}, 0.1,
     Verdict.CACHE_DEGRADATION),
    ({"service": 40.0, "queueing": 2.0}, None,
     Verdict.SERVICE_REGRESSION),
], ids=["queueing", "reroute", "retry", "cold", "cache", "service"])
def test_component_mix_maps_to_expected_verdict(comps, hit_rate, expect):
    d = BreachDiagnoser()
    for _ in range(5):
        d.update_baseline(dict(CALM), hit_rate=0.5)
    got = d.diagnose(1.0, "p95", comps, p_ms=300.0, target_ms=100.0,
                     burn=5.0, hit_rate=hit_rate)
    assert got.verdict is expect
    assert got.excess_ms > 0 and got.table()
    by_name = {e.component: e for e in got.evidence}
    assert by_name["service"].baseline_ms == pytest.approx(2.0)
    assert sum(e.share for e in got.evidence) == pytest.approx(1.0)


def test_engine_diagnoses_breach_windows_against_calm_baseline():
    eng, feed = _engine(rules=(BurnRateRule(2, 1, 1.0),)), _Feed()
    for _ in range(6):
        eng.on_window(feed.window(np.full(30, 4.0),
                                  comps={"service": 3.0, "queueing": 0.5}))
    assert not eng.diagnoses
    out = eng.on_window(feed.window(np.full(30, 400.0),
                                    comps={"service": 3.0,
                                           "queueing": 300.0}))
    assert len(out) == 1 and out[0] is eng.diagnoses[0]
    d = out[0]
    assert d.verdict is Verdict.QUEUEING_SATURATION
    assert d.p_ms == pytest.approx(400.0, rel=0.05)
    assert d.burn == pytest.approx(20.0)
    # breach windows must NOT contaminate the calm baseline
    assert eng.diagnoser.baseline["queueing"] == pytest.approx(0.5)


def test_incident_log_absorbs_leadin_and_stitches_actions():
    log = IncidentLog()
    d = Diagnosis(1.0, "p95", Verdict.QUEUEING_SATURATION, (), 300.0,
                  100.0, 5.0)
    a = ControlAction(1.0, "p95", "QUEUEING_SATURATION", "scale_out", 2)
    log.on_diagnosis(d)
    log.on_action(a)
    assert not log.incidents               # nothing open yet
    log.on_alert(AlertEvent(2.0, "p95", "fire", 3.0, 5.0, 0))
    inc = log.incidents[0]
    assert inc.diagnoses == [d] and inc.actions == [a]
    assert inc.peak_ms == 300.0
    log.on_alert(AlertEvent(4.0, "p95", "clear", 0.1, 0.0, 0))
    assert inc.t_end == 4.0
    kinds = [k for (_, k, _) in inc.timeline()]
    assert kinds == ["diagnosis", "action", "alert", "alert"]
    assert inc.dominant_verdict == "QUEUEING_SATURATION"
    # an incident still open at end of run keeps t_end=None without a
    # horizon, and gets one when the engine finalizes with one
    log.on_alert(AlertEvent(5.0, "p95", "fire", 3.0, 5.0, 0))
    log.close_all()
    assert log.incidents[1].t_end is None


# ------------------------------------------------ diagnosis-driven policy


def _tuned_fleet(count=2, **pool_kw) -> Fleet:
    fleet = Fleet([Pool("cpu", NodeSpec(cpu=CPU, batch_size=8),
                        count=count, **pool_kw)])
    fleet.estimate_capacity(100.0, n_queries=200)
    return fleet


def _diag(verdict: Verdict, burn: float = 5.0) -> Diagnosis:
    return Diagnosis(1.0, "p95", verdict, (), 300.0, 100.0, burn)


def test_policy_actions_match_verdicts():
    fleet = _tuned_fleet(count=2, max_count=16)
    pol = DiagnosisPolicy(Autoscaler(sla_ms=100.0, cooldown_windows=0))
    cap = fleet.total_capacity()

    pol.inform([_diag(Verdict.QUEUEING_SATURATION)])
    delta = pol.observe(1.0, 300.0, 2.0 * cap, fleet)
    assert delta > 1                       # rate-sized, not one-node drip
    assert pol.actions[-1].action == "scale_out"
    assert pol.actions[-1].delta == delta

    n = fleet.n_nodes
    pol.inform([_diag(Verdict.FAULT_RECOVERY)])
    assert pol.observe(2.0, 300.0, 0.2 * cap, fleet) == 0
    assert pol.actions[-1].action == "hold" and fleet.n_nodes == n

    pol.inform([_diag(Verdict.COLD_CAPACITY)], booting=2)
    assert pol.observe(3.0, 300.0, 0.2 * cap, fleet) == 0
    assert pol.actions[-1].action == "hold"
    pol.inform([_diag(Verdict.COLD_CAPACITY)], booting=0)
    assert pol.observe(4.0, 300.0, 0.2 * cap, fleet) == 1
    assert pol.actions[-1].action == "prewarm"

    pol.inform([_diag(Verdict.SERVICE_REGRESSION)])
    pol.observe(5.0, 10.0, 0.2 * cap, fleet)
    assert pol.actions[-1].action == "delegate"

    # calm windows delegate wholesale — no ControlAction recorded
    seen = len(pol.actions)
    pol.observe(6.0, 10.0, 0.2 * cap, fleet)
    assert len(pol.actions) == seen
    assert pol.events is pol.scaler.events

    pol.reset()
    assert not pol.actions and not pol.events


def test_worst_burn_objective_decides():
    fleet = _tuned_fleet(count=2, max_count=16)
    pol = DiagnosisPolicy(Autoscaler(sla_ms=100.0, cooldown_windows=0))
    pol.inform([_diag(Verdict.QUEUEING_SATURATION, burn=2.0),
                _diag(Verdict.FAULT_RECOVERY, burn=9.0)])
    assert pol.observe(1.0, 300.0, fleet.total_capacity(), fleet) == 0
    assert pol.actions[-1].verdict == "FAULT_RECOVERY"


# ------------------------------------------------------ engine integration


def _overload_run(slo=None, autoscaler=None, n=600, horizon=0.3, count=2,
                  service_s=4e-2, telemetry=True, seed=0):
    rng = np.random.default_rng(seed)
    times = np.sort(rng.uniform(0.0, horizon, n))
    sizes = rng.integers(1, 17, n).astype(np.int64)
    spec = NodeSpec(cpu=_canned(service_s), n_executors=2, batch_size=16,
                    request_overhead_s=0.0)
    fleet = Fleet([Pool("cpu", spec, count=count)])
    return drive_fleet(times, sizes, sim_backends(fleet.node_views()),
                       make_router("round_robin"), window_s=0.05,
                       telemetry=telemetry, autoscaler=autoscaler, slo=slo)


def test_drive_fleet_slo_queueing_overload_end_to_end(tmp_path, capsys):
    eng = SloEngine(SloObjective("p95", latency_ms=50.0),
                    rules=(BurnRateRule(2, 1, 1.0),))
    r = _overload_run(slo=eng)
    assert r.slo is eng
    assert eng.diagnoses
    verdicts = {d.verdict for d in eng.diagnoses}
    assert verdicts == {Verdict.QUEUEING_SATURATION}
    assert eng.violation_minutes() > 0
    assert [a.kind for a in eng.alerts][0] == "fire"
    assert eng.incidents and eng.incidents[0].t_end is not None
    # finalize attached a per-incident attribution over the breach span
    att = eng.incidents[0].attribution
    assert att is not None and att.reconciles(0.05)
    # the SLO folds must not break the run-level closure either
    assert r.telemetry.attribution().reconciles(0.05)

    # exporter round-trip: slo records ride the same JSONL artifact...
    path = os.path.join(tmp_path, "run.jsonl")
    write_jsonl(r, path)
    with open(path) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    kinds = {ln["kind"] for ln in lines}
    assert {"slo_objective", "alert", "diagnosis", "incident"} <= kinds
    inc = next(ln for ln in lines if ln["kind"] == "incident")
    assert inc["dominant_verdict"] == "QUEUEING_SATURATION"
    assert inc["worst"]["evidence"]
    # ...and the postmortem CLI renders them
    from repro.obs.report import main as report_main
    assert report_main([path]) == 0
    out = capsys.readouterr().out
    assert "QUEUEING_SATURATION" in out and "worst window" in out


def test_slo_requires_windows_and_calm_run_is_quiet():
    with pytest.raises(ValueError, match="window_s"):
        drive_fleet(np.array([0.0]), np.array([1]), sim_backends(Fleet(
            [Pool("cpu", NodeSpec(cpu=_canned(1e-4)), count=1)]
        ).node_views()), make_router("round_robin"),
            slo=SloEngine(SloObjective("p", latency_ms=50.0)))
    eng = SloEngine(SloObjective("p95", latency_ms=50.0),
                    rules=(BurnRateRule(2, 1, 1.0),))
    r = _overload_run(slo=eng, n=60, horizon=1.0, service_s=2e-4)
    assert not eng.alerts and not eng.diagnoses and not eng.incidents
    assert eng.violation_minutes() == 0.0
    assert r.slo is eng and len(eng.track["p95"]) > 0


def test_slo_engine_resets_between_runs():
    eng = SloEngine(SloObjective("p95", latency_ms=50.0),
                    rules=(BurnRateRule(2, 1, 1.0),),
                    diagnoser=BreachDiagnoser(dominant_frac=0.4))
    _overload_run(slo=eng)
    first = (len(eng.diagnoses), len(eng.alerts),
             eng.violation_minutes())
    assert first[0] > 0
    _overload_run(slo=eng)                 # driver resets at entry
    assert (len(eng.diagnoses), len(eng.alerts),
            eng.violation_minutes()) == first
    assert eng.diagnoser.dominant_frac == 0.4   # tuning survives reset


# --------------------------------------------- autoscaler signal source


def test_autoscaler_scalar_and_signal_sources_agree_on_clear_margin():
    def run(signal):
        rng = np.random.default_rng(3)
        n, horizon = 900, 1.5
        times = np.sort(rng.uniform(0.0, horizon, n))
        sizes = rng.integers(1, 17, n).astype(np.int64)
        fleet = Fleet([Pool("cpu", NodeSpec(cpu=CPU, batch_size=8),
                            count=2, max_count=12)])
        fleet.estimate_capacity(100.0, n_queries=200)
        scaler = Autoscaler(sla_ms=100.0, cooldown_windows=0, signal=signal)
        simulate_fleet(times, sizes, fleet, make_router("round_robin"),
                       window_s=0.25, autoscaler=scaler, telemetry=True)
        return [(e.t_s, e.pool, e.delta, e.reason) for e in scaler.events]

    scalar_events = run(None)
    signal_events = run(TelemetrySignal())
    assert scalar_events == signal_events
    assert scalar_events                    # the scenario actually scales


def test_telemetry_signal_reads_latest_window_or_falls_back():
    sig = TelemetrySignal()
    assert sig.window_p95_ms() is None      # unbound -> scalar fallback
    scaler = Autoscaler(sla_ms=100.0, signal=sig)
    assert scaler._p95(42.0) == 42.0

    class _Tel:
        timeline = FleetTimeline()
    reg = MetricsRegistry()
    reg.histogram("fleet_latency_ms").observe_many(np.full(50, 200.0))
    _Tel.timeline.snapshot(reg, 0.0, 0.5)
    sig.bind(_Tel)
    assert sig.window_p95_ms() == pytest.approx(200.0, rel=0.05)
    assert scaler._p95(42.0) == pytest.approx(200.0, rel=0.05)


# -------------------------------------------- sim-vs-live consistency


def test_sim_and_live_engines_agree_on_verdict():
    """The same saturating trace through the analytic sim and real
    runtime threads must diagnose the same cause."""
    service_s = 5e-3
    n = 200
    rng = np.random.default_rng(4)
    times = np.sort(rng.uniform(0.0, 0.1, n))
    sizes = rng.integers(1, 9, n).astype(np.int64)

    def engine():
        return SloEngine(SloObjective("p95", latency_ms=30.0),
                         rules=(BurnRateRule(2, 1, 1.0),))

    sim_eng = engine()
    drive_fleet(times, sizes,
                sim_backends(Fleet([Pool("cpu", NodeSpec(
                    cpu=_canned(service_s), n_executors=1, batch_size=2,
                    request_overhead_s=0.0), count=2)]).node_views()),
                make_router("round_robin"), window_s=0.1, slo=sim_eng)

    def apply_fn(batch):
        import time as _t
        _t.sleep(service_s)
        return batch["x"].sum()

    backends = [live_node(apply_fn, lambda size, model_id:
                          {"x": np.ones(size, np.float32)},
                          pool="live", index_in_pool=i,
                          device=_canned(service_s), batch_size=2,
                          max_bucket=64, clock=WallClock())
                for i in range(2)]
    live_eng = engine()
    try:
        drive_fleet(times, sizes, backends, make_router("round_robin"),
                    window_s=0.1, slo=live_eng)
    finally:
        for b in backends:
            b.close()

    for eng in (sim_eng, live_eng):
        assert eng.diagnoses, "saturating trace must breach on both engines"
        worst = max(eng.diagnoses, key=lambda d: d.burn)
        assert worst.verdict is Verdict.QUEUEING_SATURATION


# ------------------------------------------------------------ report CLI


def test_report_cli_rejects_artifacts_without_slo(tmp_path, capsys):
    from repro.obs.report import main as report_main
    r = _overload_run(n=60, horizon=1.0, service_s=2e-4)
    path = os.path.join(tmp_path, "calm.jsonl")
    write_jsonl(r, path)
    assert report_main([path]) == 1
    assert "no SLO records" in capsys.readouterr().err


def test_report_renders_calm_engine_as_no_incidents():
    eng = SloEngine(SloObjective("p95", latency_ms=50.0),
                    rules=(BurnRateRule(2, 1, 1.0),))
    r = _overload_run(slo=eng, n=60, horizon=1.0, service_s=2e-4)
    lines = [json.loads(s) for s in
             (json.dumps(x) for x in _stream(r))]
    text = report_render(lines)
    assert "incidents: none" in text


def _stream(result):
    from repro.obs.export import run_lines
    return run_lines(result)
