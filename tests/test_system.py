"""End-to-end behaviour tests for the whole system.

1. Training reduces loss on planted-signal data (recsys, LM, GNN).
2. DeepRecSched (full pipeline: measured curves → simulator → hill-climb)
   beats the paper's static baseline.
3. The numpy fast-path simulator is equivalent to the event-driven
   reference (and fault/contention runs still route through the reference).
4. Roofline parsing on a real compiled module.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.latency_model import (GPU_1080TI, AnalyticalDeviceModel,
                                      ContentionModel, TableDeviceModel)
from repro.core.query_gen import (LOGNORMAL, PRODUCTION, SizeDist,
                                  generate_queries)
from repro.core.scheduler import static_baseline, tune
from repro.core.simulator import (FaultConfig, SchedulerConfig,
                                  max_qps_under_sla, simulate)
from repro.data import synthetic as syn
from repro.models import gnn, lm, recsys
from repro.train import optim
from repro.train.loop import train

KEY = jax.random.PRNGKey(0)

CPU_TABLE = TableDeviceModel(np.array([1., 4, 16, 64, 256, 1024]),
                             np.array([.0008, .001, .0018, .0045, .015, .058]))
ACCEL = AnalyticalDeviceModel(
    flops_per_sample=2e9, mem_bytes_per_sample=4e6, in_bytes_per_sample=4e4,
    **GPU_1080TI)


def _stream(make_batch):
    while True:
        yield make_batch()


def test_train_recsys_loss_decreases():
    cfg = configs.get("dlrm-rmc1").smoke_config
    params = recsys.init(KEY, cfg)
    rng = np.random.default_rng(0)
    batches = _stream(lambda: syn.recsys_batch(rng, cfg, 64))
    first = float(recsys.loss_fn(params, cfg, syn.recsys_batch(
        np.random.default_rng(1), cfg, 512)))
    state = train(lambda p, b: recsys.loss_fn(p, cfg, b), optim.adamw(1e-2),
                  params, batches, num_steps=60, log_every=0)
    last = float(recsys.loss_fn(state.params, cfg, syn.recsys_batch(
        np.random.default_rng(1), cfg, 512)))
    assert last < first - 0.02, (first, last)


def test_train_lm_loss_decreases():
    cfg = configs.get("qwen2-0.5b").smoke_config
    params = lm.init(KEY, cfg)
    rng = np.random.default_rng(0)
    batches = _stream(lambda: syn.lm_batch(rng, cfg, 8, 32))
    eval_b = syn.lm_batch(np.random.default_rng(1), cfg, 16, 32)
    first = float(lm.loss_fn(params, cfg, eval_b))
    state = train(lambda p, b: lm.loss_fn(p, cfg, b), optim.adamw(3e-3),
                  params, batches, num_steps=50, log_every=0)
    last = float(lm.loss_fn(state.params, cfg, eval_b))
    assert last < first - 0.3, (first, last)     # Markov structure is learnable


def test_train_gnn_accuracy_improves():
    cfg = configs.get("gcn-cora").smoke_config
    params = gnn.init(KEY, cfg)
    rng = np.random.default_rng(0)
    g = syn.random_graph(rng, 200, 1600, cfg.d_feat, cfg.n_classes)

    def acc(p):
        logits = gnn.forward(p, cfg, g["x"], g["edge_index"])
        return float((jnp.argmax(logits, -1) == g["labels"]).mean())

    a0 = acc(params)
    state = train(lambda p, b: gnn.loss_fn(p, cfg, b), optim.adamw(5e-2),
                  params, _stream(lambda: g), num_steps=40, log_every=0)
    assert acc(state.params) > max(a0 + 0.2, 0.5)


def test_deeprecsched_beats_static_end_to_end():
    """The headline reproduction at test scale: tuned vs static ≥ 1.2× (the
    full benchmark shows ~2× across the 8-model suite; here one model, few
    queries, coarse search)."""
    sla = 100.0
    b0 = static_baseline(1000, 40)
    q0 = max_qps_under_sla(CPU_TABLE, SchedulerConfig(batch_size=b0), sla,
                           n_queries=800, iters=6)
    r = tune(CPU_TABLE, sla, n_queries=800)
    assert r.qps > 1.2 * q0, (r.qps, q0)


# --------------------------------------- fast-path simulator equivalence


@pytest.mark.parametrize("dist", [PRODUCTION, LOGNORMAL,
                                  SizeDist("fixed", mean=64.0)],
                         ids=["production", "lognormal", "fixed"])
@pytest.mark.parametrize("batch,thr", [
    (1, None),      # constant service time → vectorized Lindley chains
    (4, None), (25, None),
    (8, 150),       # mixed CPU + accelerator
    (16, 400),
    (32, 1),        # everything offloaded → accelerator Lindley (1 server)
])
def test_fast_simulator_matches_event_reference(batch, thr, dist):
    """Property-style grid over batch sizes, offload thresholds and size
    distributions: both engines must report the same SimResult."""
    qs = generate_queries(np.random.default_rng(2), 400.0, 600, dist)
    cfg = SchedulerConfig(batch_size=batch, offload_threshold=thr)
    accel = ACCEL if thr is not None else None
    rf = simulate(qs, CPU_TABLE, cfg, accel=accel, engine="fast")
    re = simulate(qs, CPU_TABLE, cfg, accel=accel, engine="events")
    for field in ("qps", "p50_ms", "p95_ms", "p99_ms", "mean_ms",
                  "cpu_util", "accel_frac_work"):
        np.testing.assert_allclose(getattr(rf, field), getattr(re, field),
                                   rtol=1e-6, atol=1e-9, err_msg=field)
    assert (rf.n_queries, rf.dropped) == (re.n_queries, re.dropped)


def test_fast_qps_search_within_5pct_of_reference():
    cfg = SchedulerConfig(batch_size=8)
    q_fast = max_qps_under_sla(CPU_TABLE, cfg, 100.0, n_queries=500, iters=7)
    q_ref = max_qps_under_sla(CPU_TABLE, cfg, 100.0, n_queries=500, iters=7,
                              engine="events")
    assert abs(q_fast - q_ref) <= 0.05 * q_ref, (q_fast, q_ref)


def test_warm_started_qps_search_within_5pct_of_cold():
    cfg = SchedulerConfig(batch_size=16)
    cold = max_qps_under_sla(CPU_TABLE, cfg, 100.0, n_queries=500, iters=7)
    for hint in (cold, cold * 0.6, cold * 1.7, 2.0):
        warm = max_qps_under_sla(CPU_TABLE, cfg, 100.0, n_queries=500,
                                 iters=7, hint=hint)
        assert abs(warm - cold) <= 0.05 * cold, (hint, warm, cold)


def test_empty_pool_drops_like_reference():
    """n_accelerators=0 with offloading (or n_executors=0) must report the
    same dropped counts as the reference, not garbage departures."""
    qs = generate_queries(np.random.default_rng(4), 400.0, 200)
    cfg = SchedulerConfig(batch_size=8, offload_threshold=200,
                          n_accelerators=0)
    rf = simulate(qs, CPU_TABLE, cfg, accel=ACCEL, engine="fast")
    re = simulate(qs, CPU_TABLE, cfg, accel=ACCEL, engine="events")
    assert (rf.n_queries, rf.dropped) == (re.n_queries, re.dropped)
    assert rf.dropped > 0
    np.testing.assert_allclose(rf.p95_ms, re.p95_ms, rtol=1e-6)
    for eng in ("fast", "events"):
        r0 = simulate(qs, CPU_TABLE,
                      SchedulerConfig(batch_size=8, n_executors=0),
                      engine=eng)
        assert (r0.n_queries, r0.dropped) == (0, len(qs)), eng


def test_warm_start_hint_honors_lo_floor():
    """An infeasible hint must not re-bracket below the caller's lo."""
    cfg = SchedulerConfig(batch_size=8)
    cold = max_qps_under_sla(CPU_TABLE, cfg, 0.0001, lo=200.0, n_queries=300,
                             iters=7)
    warm = max_qps_under_sla(CPU_TABLE, cfg, 0.0001, lo=200.0, n_queries=300,
                             iters=7, hint=300.0)
    assert cold == 200.0 and warm >= 200.0, (cold, warm)


def test_parallel_ladder_matches_sequential_choice():
    """tune(workers=N) evaluates ladders eagerly in a process pool but must
    pick the same config as the sequential patience walk."""
    r_seq = tune(CPU_TABLE, 100.0, accel=ACCEL, n_queries=400,
                 warm_start=False)
    r_par = tune(CPU_TABLE, 100.0, accel=ACCEL, n_queries=400, workers=2)
    assert (r_seq.batch_size, r_seq.offload_threshold) == \
        (r_par.batch_size, r_par.offload_threshold)
    assert r_par.qps == r_seq.qps


def test_fault_and_contention_runs_route_through_reference():
    """With any fault/contention knob active, engine='auto' must produce the
    *identical* SimResult the event-driven reference produces."""
    qs = generate_queries(np.random.default_rng(3), 300.0, 300)
    cfg = SchedulerConfig(batch_size=8)
    faults = FaultConfig(straggler_frac=0.05, straggler_mult=4.0,
                         hedge_factor=3.0, fail_times=(0.5,))
    assert simulate(qs, CPU_TABLE, cfg, faults=faults, seed=1) == \
        simulate(qs, CPU_TABLE, cfg, faults=faults, seed=1, engine="events")
    cont = ContentionModel(factor_at_full=1.6)
    assert simulate(qs, CPU_TABLE, cfg, contention=cont) == \
        simulate(qs, CPU_TABLE, cfg, contention=cont, engine="events")
    with pytest.raises(ValueError):
        simulate(qs, CPU_TABLE, cfg, faults=faults, engine="fast")


def test_roofline_parses_compiled_module():
    from repro.roofline import analysis as ra
    def f(x, w):
        return jnp.tanh(x @ w).sum()
    comp = jax.jit(f).lower(jnp.ones((128, 64)), jnp.ones((64, 32))).compile()
    rf = ra.from_compiled(comp, chips=1, model_flops=2 * 128 * 64 * 32)
    assert rf.flops > 0
    assert rf.t_compute > 0 and rf.t_memory > 0
    assert rf.bottleneck in ("compute", "memory", "collective")


def test_collective_bytes_parser():
    from repro.roofline.analysis import collective_bytes
    hlo = """
  %ar = f32[1024,256]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,8]<=[16], to_apply=%add
  %ag = bf16[512,128]{1,0} all-gather(%y), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2 * 1024 * 256 * 4 * 7 // 8
    assert out["all-gather"] == 512 * 128 * 2 * 3 // 4
    assert out["collective-permute"] == 64 * 4
