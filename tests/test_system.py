"""End-to-end behaviour tests for the whole system.

1. Training reduces loss on planted-signal data (recsys, LM, GNN).
2. DeepRecSched (full pipeline: measured curves → simulator → hill-climb)
   beats the paper's static baseline.
3. Roofline parsing on a real compiled module.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.latency_model import TableDeviceModel
from repro.core.scheduler import static_baseline, tune
from repro.core.simulator import SchedulerConfig, max_qps_under_sla
from repro.data import synthetic as syn
from repro.models import gnn, lm, recsys
from repro.train import optim
from repro.train.loop import train

KEY = jax.random.PRNGKey(0)


def _stream(make_batch):
    while True:
        yield make_batch()


def test_train_recsys_loss_decreases():
    cfg = configs.get("dlrm-rmc1").smoke_config
    params = recsys.init(KEY, cfg)
    rng = np.random.default_rng(0)
    batches = _stream(lambda: syn.recsys_batch(rng, cfg, 64))
    first = float(recsys.loss_fn(params, cfg, syn.recsys_batch(
        np.random.default_rng(1), cfg, 512)))
    state = train(lambda p, b: recsys.loss_fn(p, cfg, b), optim.adamw(1e-2),
                  params, batches, num_steps=60, log_every=0)
    last = float(recsys.loss_fn(state.params, cfg, syn.recsys_batch(
        np.random.default_rng(1), cfg, 512)))
    assert last < first - 0.02, (first, last)


def test_train_lm_loss_decreases():
    cfg = configs.get("qwen2-0.5b").smoke_config
    params = lm.init(KEY, cfg)
    rng = np.random.default_rng(0)
    batches = _stream(lambda: syn.lm_batch(rng, cfg, 8, 32))
    eval_b = syn.lm_batch(np.random.default_rng(1), cfg, 16, 32)
    first = float(lm.loss_fn(params, cfg, eval_b))
    state = train(lambda p, b: lm.loss_fn(p, cfg, b), optim.adamw(3e-3),
                  params, batches, num_steps=50, log_every=0)
    last = float(lm.loss_fn(state.params, cfg, eval_b))
    assert last < first - 0.3, (first, last)     # Markov structure is learnable


def test_train_gnn_accuracy_improves():
    cfg = configs.get("gcn-cora").smoke_config
    params = gnn.init(KEY, cfg)
    rng = np.random.default_rng(0)
    g = syn.random_graph(rng, 200, 1600, cfg.d_feat, cfg.n_classes)

    def acc(p):
        logits = gnn.forward(p, cfg, g["x"], g["edge_index"])
        return float((jnp.argmax(logits, -1) == g["labels"]).mean())

    a0 = acc(params)
    state = train(lambda p, b: gnn.loss_fn(p, cfg, b), optim.adamw(5e-2),
                  params, _stream(lambda: g), num_steps=40, log_every=0)
    assert acc(state.params) > max(a0 + 0.2, 0.5)


def test_deeprecsched_beats_static_end_to_end():
    """The headline reproduction at test scale: tuned vs static ≥ 1.2× (the
    full benchmark shows ~2× across the 8-model suite; here one model, few
    queries, coarse search)."""
    cpu = TableDeviceModel(np.array([1., 4, 16, 64, 256, 1024]),
                           np.array([.0008, .001, .0018, .0045, .015, .058]))
    sla = 100.0
    b0 = static_baseline(1000, 40)
    q0 = max_qps_under_sla(cpu, SchedulerConfig(batch_size=b0), sla,
                           n_queries=800, iters=6)
    r = tune(cpu, sla, n_queries=800)
    assert r.qps > 1.2 * q0, (r.qps, q0)


def test_roofline_parses_compiled_module():
    from repro.roofline import analysis as ra
    def f(x, w):
        return jnp.tanh(x @ w).sum()
    comp = jax.jit(f).lower(jnp.ones((128, 64)), jnp.ones((64, 32))).compile()
    rf = ra.from_compiled(comp, chips=1, model_flops=2 * 128 * 64 * 32)
    assert rf.flops > 0
    assert rf.t_compute > 0 and rf.t_memory > 0
    assert rf.bottleneck in ("compute", "memory", "collective")


def test_collective_bytes_parser():
    from repro.roofline.analysis import collective_bytes
    hlo = """
  %ar = f32[1024,256]{1,0} all-reduce(%x), channel_id=1, replica_groups=[2,8]<=[16], to_apply=%add
  %ag = bf16[512,128]{1,0} all-gather(%y), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = f32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 2 * 1024 * 256 * 4 * 7 // 8
    assert out["all-gather"] == 512 * 128 * 2 * 3 // 4
    assert out["collective-permute"] == 64 * 4
