"""Optimizers, checkpointing (incl. preemption + corruption), microbatching,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.train import grad_compress as gc
from repro.train import optim
from repro.train.loop import make_train_step, train
from repro.train.microbatch import accumulated_grads

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------- optimizers


@pytest.mark.parametrize("opt", [optim.sgd(0.1), optim.sgd(0.05, momentum=0.9),
                                 optim.adagrad(0.5), optim.adamw(0.05)])
def test_optimizer_minimizes_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_combined_routes_by_path():
    params = {"tables": jnp.ones(4), "mlp": jnp.ones(4)}
    opt = optim.combined(lambda p: "tables" in str(p),
                         optim.sgd(1.0), optim.sgd(0.0))
    state = opt.init(params)
    new, _ = opt.update({"tables": jnp.ones(4), "mlp": jnp.ones(4)}, state, params)
    assert float(new["tables"][0]) == 0.0          # lr 1 applied
    assert float(new["mlp"][0]) == 1.0             # lr 0 applied


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    c = optim.clip_by_global_norm(g, 1.0)
    assert abs(float(optim.global_norm(c)) - 1.0) < 1e-5


# ------------------------------------------------------------ microbatch


def test_accumulated_grads_match_full_batch():
    w = jnp.array([1.0, 2.0])
    batch = {"x": jnp.arange(8.0).reshape(8, 1), "y": jnp.ones((8,))}

    def loss(params, b):
        pred = (b["x"] * params[0] + params[1])[:, 0]
        return ((pred - b["y"]) ** 2).mean()

    l1, g1 = accumulated_grads(loss, w, batch, 1)
    l4, g4 = accumulated_grads(loss, w, batch, 4)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g4), rtol=1e-5)


# ------------------------------------------------------------ checkpoint


def _tree():
    return {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3),
            "nested": {"t": jnp.zeros((4,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 5, t)
    restored, step = ck.restore(str(tmp_path), t)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep=2)
    assert ck.all_steps(str(tmp_path)) == [4, 5]
    assert ck.latest_step(str(tmp_path)) == 5


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    path = ck.save(str(tmp_path), 1, t)
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data["leaf_00000"] = data["leaf_00000"] + 1
    np.savez(npz, **data)
    with pytest.raises(IOError, match="checksum"):
        ck.restore(str(tmp_path), t)


def test_preemption_resume_bit_exact(tmp_path):
    """Kill training at step 7, resume, reach the same state as an
    uninterrupted run (fault tolerance contract)."""
    def batches():
        rng = np.random.default_rng(42)
        while True:
            x = rng.normal(size=(16, 4)).astype(np.float32)
            yield {"x": jnp.asarray(x),
                   "y": jnp.asarray(x.sum(1, keepdims=True))}

    def loss(params, b):
        return ((b["x"] @ params["w"] - b["y"]) ** 2).mean()

    init = {"w": jnp.zeros((4, 1))}
    opt = optim.adamw(0.01)

    # uninterrupted 12 steps
    full = train(loss, opt, init, batches(), num_steps=12, ckpt_dir=None,
                 log_every=0)
    # interrupted: run 7 (ckpt at 5), "crash", resume to 12
    d1 = str(tmp_path / "ck")
    train(loss, opt, init, batches(), num_steps=7, ckpt_dir=d1, ckpt_every=5,
          log_every=0)
    # resume skips the first `start` batches? No: data stream is stateless
    # per-step here; emulate by re-feeding the same stream and letting the
    # loop fast-forward.
    def batches_from(start):
        g = batches()
        for _ in range(start):
            next(g)
        return g
    resumed = train(loss, opt, init, batches_from(7), num_steps=12,
                    ckpt_dir=d1, ckpt_every=5, log_every=0)
    np.testing.assert_allclose(np.asarray(full.params["w"]),
                               np.asarray(resumed.params["w"]),
                               rtol=1e-6, atol=1e-6)


def test_nan_guard_skips_update():
    def loss(params, b):
        return jnp.where(b["bad"], jnp.nan, (params["w"] ** 2).sum())

    step = make_train_step(loss, optim.sgd(0.1), donate=False)
    params = {"w": jnp.array([1.0])}
    state = ()
    p2, state, m = step(params, state, {"bad": jnp.array(True)})
    assert not bool(m["finite"])
    np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))


# -------------------------------------------------------- grad compression


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (1000,)) * 5
    q, s = gc.quantize_int8(x)
    y = gc.dequantize_int8(q, s, x.shape, jnp.float32)
    err = np.abs(np.asarray(x - y))
    block_max = np.abs(np.asarray(x)).reshape(-1, 250).max()  # loose bound
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127 + 1e-6


def test_error_feedback_accumulates_lost_mass():
    grads = {"w": jnp.full((300,), 1e-3)}
    res = gc.init_error_feedback(grads)
    total = jnp.zeros((300,))
    for _ in range(50):
        q, res = gc.compress_grads(grads, res)
        total = total + gc.decompress_grads(q, grads)["w"]
    # with EF, the long-run mean of dequantized grads ≈ true grad
    np.testing.assert_allclose(np.asarray(total) / 50, 1e-3, rtol=0.05)
